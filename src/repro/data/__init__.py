"""repro.data"""
