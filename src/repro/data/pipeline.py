"""Deterministic, resumable synthetic data pipelines.

Every batch is a pure function of (seed, step) — after a restart the loader
resumes from the checkpointed step with bit-identical data and no shared state
between hosts (each host slices its own shard of the global batch, the
standard multi-host pattern).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hmm import HMM


@dataclasses.dataclass(frozen=True)
class TokenPipelineConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    num_image_tokens: int = 0
    d_model: int = 0              # for embeds/image modalities
    kind: str = "tokens"          # tokens | embeds | vlm


class SyntheticTokenPipeline:
    """Markov-ish synthetic token stream (not iid — gives learnable structure
    so the end-to-end example's loss demonstrably decreases)."""

    def __init__(self, cfg: TokenPipelineConfig):
        self.cfg = cfg

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng((self.cfg.seed * 1_000_003 + step) & 0x7FFFFFFF)

    def batch(self, step: int) -> dict:
        cfg = self.cfg
        rng = self._rng(step)
        B, S, V = cfg.global_batch, cfg.seq_len, cfg.vocab
        if cfg.kind == "embeds":
            emb = rng.standard_normal((B, S, cfg.d_model), dtype=np.float32)
            labels = rng.integers(0, V, (B, S))
            mask = (rng.random((B, S)) < 0.3).astype(np.float32)  # masked pred
            return {"embeds": emb, "labels": labels.astype(np.int32),
                    "mask": mask}
        # order-1 markov chain with banded transitions: next ~ cur + U(-8, 8)
        toks = np.zeros((B, S), dtype=np.int64)
        toks[:, 0] = rng.integers(0, V, B)
        jumps = rng.integers(-8, 9, (B, S))
        for t in range(1, S):
            toks[:, t] = (toks[:, t - 1] + jumps[:, t]) % V
        labels = np.roll(toks, -1, axis=1)
        mask = np.ones((B, S), dtype=np.float32)
        mask[:, -1] = 0.0
        out = {"tokens": toks.astype(np.int32), "labels": labels.astype(np.int32),
               "mask": mask}
        if cfg.kind == "vlm":
            n = cfg.num_image_tokens
            out["tokens"] = out["tokens"][:, : S - n]
            out["image_embeds"] = rng.standard_normal(
                (B, n, cfg.d_model), dtype=np.float32)
            out["mask"][:, :n] = 0.0
        return out

    def sharded_batch(self, step: int, shardings) -> dict:
        """Device-put a host batch with the given sharding tree."""
        host = self.batch(step)
        return jax.tree_util.tree_map(
            lambda x, s: jax.device_put(jnp.asarray(x), s), host, shardings)


@dataclasses.dataclass(frozen=True)
class EmissionPipelineConfig:
    num_states: int
    seq_len: int
    batch: int
    seed: int = 0


class HMMEmissionPipeline:
    """Batches of (T, K) emission matrices for the decoding benchmarks and the
    alignment-serving path (deterministic per step, like the token pipeline)."""

    def __init__(self, cfg: EmissionPipelineConfig, hmm: HMM):
        self.cfg = cfg
        self.hmm = hmm

    def batch(self, step: int):
        key = jax.random.fold_in(jax.random.key(self.cfg.seed), step)
        ks, ko = jax.random.split(key)
        from repro.core.hmm import sample_observations
        obs = jax.vmap(lambda k: sample_observations(k, self.hmm,
                                                     self.cfg.seq_len)[1])(
            jax.random.split(ko, self.cfg.batch))
        ems = jax.vmap(self.hmm.emissions)(obs)
        return {"obs": obs, "emissions": ems}


__all__ = ["TokenPipelineConfig", "SyntheticTokenPipeline",
           "EmissionPipelineConfig", "HMMEmissionPipeline"]
