"""Shared pure-JAX model building blocks: param tables, norms, MLPs, rotary.

No flax/haiku — parameters are nested dicts of arrays, created from *layout
tables* `{name: (shape, logical_axes, init_kind)}`.  The same table yields the
init values, the PartitionSpec tree (via sharding.rules), and the parameter
count, so the three can never drift apart.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.sharding.rules import ShardingRules

Layout = dict  # {name: (shape, logical_axes, init_kind) | nested Layout}


# ---------------------------------------------------------------------------
# Param tables
# ---------------------------------------------------------------------------

def _init_array(key, shape, kind: str, dtype):
    if kind == "zeros":
        return jnp.zeros(shape, dtype)
    if kind == "ones":
        return jnp.ones(shape, dtype)
    if kind == "normal":
        fan_in = shape[0] if len(shape) > 1 else shape[-1]
        scale = 1.0 / math.sqrt(max(fan_in, 1))
        return (jax.random.normal(key, shape) * scale).astype(dtype)
    if kind == "embed":
        return (jax.random.normal(key, shape) * 0.02).astype(dtype)
    if kind == "rglru_a":  # see rglru.py: softplus^-1 spaced for stable decay
        lo, hi = 0.9, 0.999
        u = np.linspace(lo, hi, shape[-1])
        val = np.log(np.expm1(-np.log(u) / (8.0 / 256)))  # inverse softplus
        return jnp.broadcast_to(jnp.asarray(val, dtype), shape)
    raise ValueError(f"unknown init kind {kind!r}")


def init_params(key: jax.Array, layout: Layout, dtype=jnp.bfloat16):
    """Materialise a parameter pytree from a layout table."""
    flat = []

    def count(l):
        return sum(count(v) if isinstance(v, dict) else 1 for v in l.values())

    keys = iter(jax.random.split(key, max(count(layout), 1)))

    def build(l):
        out = {}
        for name, val in l.items():
            if isinstance(val, dict):
                out[name] = build(val)
            else:
                shape, _, kind = val
                out[name] = _init_array(next(keys), shape, kind, dtype)
        return out

    return build(layout)


def abstract_params(layout: Layout, dtype=jnp.bfloat16):
    """ShapeDtypeStruct pytree (for dry-run lowering without allocation)."""
    def build(l):
        return {name: (build(v) if isinstance(v, dict)
                       else jax.ShapeDtypeStruct(v[0], dtype))
                for name, v in l.items()}
    return build(layout)


def param_specs(rules: ShardingRules, layout: Layout):
    def build(l):
        return {name: (build(v) if isinstance(v, dict)
                       else rules.spec(*v[1]))
                for name, v in l.items()}
    return build(layout)


def param_count(layout: Layout) -> int:
    def cnt(l):
        return sum(cnt(v) if isinstance(v, dict) else int(np.prod(v[0]))
                   for v in l.values())
    return cnt(layout)


# ---------------------------------------------------------------------------
# Core ops
# ---------------------------------------------------------------------------

def rms_norm(x, scale, eps: float = 1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def layer_norm(x, scale, bias, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": lambda x: jax.nn.gelu(x, approximate=True),
            "relu": jax.nn.relu}[name]


def glu_mlp(params, x, act: str = "silu"):
    """Gated MLP (SwiGLU/GeGLU): (x W_g * act) * (x W_i) W_o."""
    g = act_fn(act)(x @ params["wg"])
    h = g * (x @ params["wi"])
    return h @ params["wo"]


def mlp(params, x, act: str = "gelu"):
    return act_fn(act)(x @ params["wi"]) @ params["wo"]


def glu_mlp_layout(d: int, f: int) -> Layout:
    return {"wg": ((d, f), ("model_d", "ff"), "normal"),
            "wi": ((d, f), ("model_d", "ff"), "normal"),
            "wo": ((f, d), ("ff", "model_d"), "normal")}


def mlp_layout(d: int, f: int) -> Layout:
    return {"wi": ((d, f), ("model_d", "ff"), "normal"),
            "wo": ((f, d), ("ff", "model_d"), "normal")}


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float = 10000.0):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta: float = 10000.0):
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                      # (hd/2,)
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs  # (...,S,1,hd/2)
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------

def chunked_cross_entropy(logits_fn, hidden, embed_t, targets, mask,
                          chunk: int = 512):
    """CE over huge vocabularies without materialising (B, S, V) at once.

    hidden: (B, S, D); embed_t: (D, V) output head; targets/mask: (B, S).
    Scans over sequence chunks; each chunk's logits live only inside the scan
    body, bounding live logits at (B, chunk, V_shard).
    """
    B, S, D = hidden.shape
    n = S // chunk
    h = hidden.reshape(B, n, chunk, D).swapaxes(0, 1)        # (n, B, c, D)
    t = targets.reshape(B, n, chunk).swapaxes(0, 1)
    m = mask.reshape(B, n, chunk).swapaxes(0, 1)

    def body(carry, xs):
        hs, ts, ms = xs
        logits = logits_fn(hs @ embed_t)                     # (B, c, V) f32
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, ts[..., None], axis=-1)[..., 0]
        nll = (logz - gold) * ms
        return (carry[0] + jnp.sum(nll), carry[1] + jnp.sum(ms)), None

    body = jax.checkpoint(body)  # recompute chunk logits in backward
    (tot, cnt), _ = jax.lax.scan(body, (jnp.float32(0), jnp.float32(0)),
                                 (h, t, m))
    return tot / jnp.maximum(cnt, 1.0)


__all__ = [
    "Layout", "init_params", "abstract_params", "param_specs", "param_count",
    "rms_norm", "layer_norm", "act_fn", "glu_mlp", "mlp", "glu_mlp_layout",
    "mlp_layout", "rope_frequencies", "apply_rope", "chunked_cross_entropy",
]
