"""Attention: GQA/MQA, sliding-window/local, encoder (bidirectional), MLA.

All full-sequence paths (train + prefill) go through `blockwise_attention` — an
online-softmax flash-attention formulation as nested `lax.scan`s, so the (S, S)
score matrix is never materialised (mandatory at the 32k prefill shapes).  KV
can be supplied in *latent* form with a per-block expansion callback, which is
how MLA (DeepSeek-V2) prefill expands its compressed KV inside the scan without
ever materialising the full expanded KV tensor.

Decode paths attend a KV cache directly (a single query position makes the
score tensor (B, H, 1, S) — small).  Caches are ring buffers: sliding-window
layers allocate only `window` slots, which is what makes the 500k-context
decode cells for SWA/hybrid archs cache-bounded instead of length-bounded.
MLA decode uses the absorbed form (latent-space attention) so the cache holds
only (kv_lora + rope_dim) floats per token — the paper-analogous memory win.

Baseline causal handling computes all KV blocks with masking (2x FLOP waste on
strictly-causal cells); see EXPERIMENTS.md §Perf for the optimised schedule.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable

import jax
import jax.numpy as jnp

from .common import Layout, apply_rope, rms_norm

_MASK_VALUE = -1e30


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    causal: bool = True
    window: int | None = None          # sliding-window size (None = full)
    rope_theta: float = 10000.0
    use_rope: bool = True
    q_block: int = 512
    kv_block: int = 1024
    # MLA (None = standard attention)
    q_lora: int | None = None
    kv_lora: int | None = None
    rope_head_dim: int = 64
    v_head_dim: int | None = None
    causal_schedule: str = "full"      # "banded": skip future KV bands


# ---------------------------------------------------------------------------
# Layouts
# ---------------------------------------------------------------------------

def attn_layout(cfg: AttnConfig) -> Layout:
    d, h, hk, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    if cfg.kv_lora is not None:
        dn, dr = cfg.head_dim, cfg.rope_head_dim
        dv = cfg.v_head_dim or cfg.head_dim
        lay: Layout = {
            "wq_a": ((d, cfg.q_lora), ("model_d", None), "normal"),
            "q_norm": ((cfg.q_lora,), (None,), "zeros"),
            "wq_b": ((cfg.q_lora, h * (dn + dr)), (None, "heads"), "normal"),
            "w_dkv": ((d, cfg.kv_lora + dr), ("model_d", None), "normal"),
            "kv_norm": ((cfg.kv_lora,), (None,), "zeros"),
            "w_uk": ((cfg.kv_lora, h * dn), (None, "heads"), "normal"),
            "w_uv": ((cfg.kv_lora, h * dv), (None, "heads"), "normal"),
            "wo": ((h * dv, d), ("heads", "model_d"), "normal"),
        }
        return lay
    kv_axis = "kv_heads" if hk > 1 else None  # MQA kv proj too small to shard
    return {
        "wq": ((d, h * hd), ("model_d", "heads"), "normal"),
        "wk": ((d, hk * hd), ("model_d", kv_axis), "normal"),
        "wv": ((d, hk * hd), ("model_d", kv_axis), "normal"),
        "wo": ((h * hd, d), ("heads", "model_d"), "normal"),
    }


# ---------------------------------------------------------------------------
# Blockwise (flash-style) attention
# ---------------------------------------------------------------------------

def blockwise_attention(q, kv_latent, expand_fn: Callable, *, causal: bool,
                        window: int | None, q_offset, kv_positions,
                        q_block: int, kv_block: int, scale: float):
    """Online-softmax attention over latent KV blocks.

    q:          (B, S, H, hd_k) queries (rope already applied).
    kv_latent:  pytree of (B, Skv, *) latent KV streams (for plain GQA the
                tuple (k, v); for MLA (c_kv, k_pe)).  Kept as separate leaves
                so tensor-parallel sharding never straddles a concat boundary
                (a packed tensor would reshard inside the scan every block).
    expand_fn:  pytree of (B, kb, *) -> (k (B, kb, H, hd_k), v (B, kb, H, hd_v)).
    kv_positions: (Skv,) int32 position of each kv slot (-1 = invalid slot).

    Returns (B, S, H, hd_v).
    """
    B, S, H, hd_k = q.shape
    Skv = jax.tree_util.tree_leaves(kv_latent)[0].shape[1]
    nq, nkv = S // q_block, Skv // kv_block

    q_r = q.reshape(B, nq, q_block, H, hd_k).swapaxes(0, 1)   # (nq, B, qb, H, dk)
    kv_r = jax.tree_util.tree_map(
        lambda a: a.reshape(B, nkv, kv_block, -1).swapaxes(0, 1), kv_latent)
    kpos_r = kv_positions.reshape(nkv, kv_block)

    def q_body(_, xs):
        qi, qb = xs                                            # index, (B,qb,H,dk)
        qpos = q_offset + qi * q_block + jnp.arange(q_block)   # (qb,)

        def kv_body(carry, kv_xs):
            m, l, acc = carry
            kv_b, kpos = kv_xs                                 # (B,kb,L), (kb,)
            k, v = expand_fn(kv_b)                             # (B,kb,H,dk/dv)
            s = jnp.einsum("bqhd,bkhd->bhqk", qf(qb), qf(k)) * scale
            valid = kpos[None, :] >= 0
            if causal:
                valid &= qpos[:, None] >= kpos[None, :]
            if window is not None:
                valid &= (qpos[:, None] - kpos[None, :]) < window
            s = jnp.where(valid[None, None, :, :], s, _MASK_VALUE)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = corr * l + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bhqk,bkhd->bqhd", p, qf(v))
            acc_new = corr.transpose(0, 2, 1)[..., None] * acc + pv
            return (m_new, l_new, acc_new), None

        hd_v = jax.eval_shape(
            expand_fn, jax.tree_util.tree_map(lambda a: a[0], kv_r))[1].shape[-1]
        init = (jnp.full((B, H, q_block), -jnp.inf, jnp.float32),
                jnp.zeros((B, H, q_block), jnp.float32),
                jnp.zeros((B, q_block, H, hd_v), jnp.float32))
        (m, l, acc), _ = jax.lax.scan(kv_body, init, (kv_r, kpos_r))
        out = acc / jnp.maximum(l, 1e-30).transpose(0, 2, 1)[..., None]
        return None, out

    # flash-attention-style remat: recompute each q-block's kv scan in the
    # backward pass instead of saving every (B, H, qb, kb) probability block
    q_body = jax.checkpoint(q_body)
    _, out = jax.lax.scan(q_body, None,
                          (jnp.arange(nq), q_r))
    return out.swapaxes(0, 1).reshape(B, S, H, -1)


def qf(x):
    return x.astype(jnp.float32)


def banded_blockwise(q, kv_latent, expand_fn, *, window, q_offset,
                     kv_positions, q_block: int, kv_block: int, scale: float,
                     bands: int = 4):
    """Causal attention with future-KV-band skipping.

    The baseline scans ALL kv blocks per q block and masks (2x FLOP waste for
    strictly-causal cells).  Splitting queries into `bands` groups, group g
    only scans kv[: (g+1) * S/bands]: executed score FLOPs drop from S^2 to
    S^2 * (bands+1) / (2*bands)  (1.25x waste at bands=4 instead of 2x),
    with `bands` x the HLO body size — the compute/compile-size knob of
    EXPERIMENTS.md §Perf.
    """
    B, S, H, dk = q.shape
    if S % bands or (S // bands) % q_block:
        bands = 1
    Sb = S // bands
    outs = []
    for g in range(bands):
        q_g = q[:, g * Sb:(g + 1) * Sb]
        end = (g + 1) * Sb
        lat_g = jax.tree_util.tree_map(lambda a: a[:, :end], kv_latent)
        outs.append(blockwise_attention(
            q_g, lat_g, expand_fn, causal=True, window=window,
            q_offset=q_offset + g * Sb, kv_positions=kv_positions[:end],
            q_block=min(q_block, Sb), kv_block=min(kv_block, end),
            scale=scale))
    return jnp.concatenate(outs, axis=1)


# ---------------------------------------------------------------------------
# Standard (GQA/MQA) attention
# ---------------------------------------------------------------------------

def _split_heads(x, n, hd):
    B, S, _ = x.shape
    return x.reshape(B, S, n, hd)


def gqa_forward(params, x, positions, cfg: AttnConfig):
    """Full-sequence GQA attention (train / prefill). Returns (out, kv_packed).

    kv_packed (B, S, Hkv*hd*2) is what prefill stores into the cache.
    """
    B, S, _ = x.shape
    h, hk, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = _split_heads(x @ params["wq"], h, hd)
    k = _split_heads(x @ params["wk"], hk, hd)
    v = _split_heads(x @ params["wv"], hk, hd)
    if cfg.use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    k_flat = k.reshape(B, S, hk * hd)
    v_flat = v.reshape(B, S, hk * hd)
    g = h // hk

    def expand(kv_b):
        k_b, v_b = kv_b
        kb = k_b.shape[1]
        k_b = k_b.reshape(B, kb, hk, 1, hd)
        v_b = v_b.reshape(B, kb, hk, 1, hd)
        k_b = jnp.broadcast_to(k_b, (B, kb, hk, g, hd)).reshape(B, kb, h, hd)
        v_b = jnp.broadcast_to(v_b, (B, kb, hk, g, hd)).reshape(B, kb, h, hd)
        return k_b, v_b

    qb = min(cfg.q_block, S)
    kb = min(cfg.kv_block, S)
    if cfg.causal_schedule == "banded" and cfg.causal and S >= 4 * qb:
        out = banded_blockwise(
            q, (k_flat, v_flat), expand, window=cfg.window,
            q_offset=positions[0], kv_positions=positions,
            q_block=qb, kv_block=kb, scale=1.0 / math.sqrt(hd))
    else:
        out = blockwise_attention(
            q, (k_flat, v_flat), expand, causal=cfg.causal, window=cfg.window,
            q_offset=positions[0], kv_positions=positions,
            q_block=qb, kv_block=kb, scale=1.0 / math.sqrt(hd))
    out = out.astype(x.dtype).reshape(B, S, h * hd)
    return out @ params["wo"], {"k": k_flat, "v": v_flat}


def gqa_decode(params, x, cache, cfg: AttnConfig):
    """Single-position decode against a ring-buffer cache.

    cache: {"k"/"v": (B, C, Hkv*hd), "pos": (C,) int32 slot positions,
            "next": () int32 next absolute position}.  k and v are separate
    entries so kv-head sharding never crosses the k/v boundary (a packed
    cache would turn the k/v split into a cache-sized collective-permute).
    """
    B, S, _ = x.shape  # S == 1
    h, hk, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    pos = cache["next"]
    positions = pos[None] + jnp.arange(S)

    q = _split_heads(x @ params["wq"], h, hd)
    k = _split_heads(x @ params["wk"], hk, hd)
    v = _split_heads(x @ params["wv"], hk, hd)
    if cfg.use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    C = cache["k"].shape[1]
    slot = pos % C
    k_c = jax.lax.dynamic_update_slice(cache["k"], k.reshape(B, S, hk * hd),
                                       (0, slot, 0))
    v_c = jax.lax.dynamic_update_slice(cache["v"], v.reshape(B, S, hk * hd),
                                       (0, slot, 0))
    kpos = jax.lax.dynamic_update_slice(cache["pos"], positions.astype(jnp.int32),
                                        (slot,))

    k_all = k_c.reshape(B, C, hk, hd)
    v_all = v_c.reshape(B, C, hk, hd)
    g = h // hk
    qg = q.reshape(B, S, hk, g, hd)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qf(qg), qf(k_all)) / math.sqrt(hd)

    valid = kpos[None, :] >= 0
    valid &= positions[:, None] >= kpos[None, :]
    if cfg.window is not None:
        valid &= (positions[:, None] - kpos[None, :]) < cfg.window
    s = jnp.where(valid[None, None, None, :, :], s, _MASK_VALUE)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, qf(v_all))
    out = out.astype(x.dtype).reshape(B, S, h * hd)
    new_cache = {"k": k_c, "v": v_c, "pos": kpos, "next": pos + S}
    return out @ params["wo"], new_cache


def gqa_init_cache(cfg: AttnConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    C = min(max_len, cfg.window) if cfg.window else max_len
    kv_shape = (batch, C, cfg.num_kv_heads * cfg.head_dim)
    return {"k": jnp.zeros(kv_shape, dtype), "v": jnp.zeros(kv_shape, dtype),
            "pos": jnp.full((C,), -1, jnp.int32),
            "next": jnp.zeros((), jnp.int32)}


def gqa_prefill_cache(cfg: AttnConfig, kv, positions, max_len: int):
    """Build a decode cache from prefill outputs (window-trimmed).

    kv: {"k": (B, S, Hkv*hd), "v": ...}.  Prefill always starts at position 0,
    so the ring alignment shift is a *static* int (a traced roll would lower
    to a full-cache gather)."""
    B, S, _ = kv["k"].shape
    C = min(max_len, cfg.window) if cfg.window else max_len
    if S >= C:  # keep last C entries, ring-aligned so slot == pos % C
        start = S - C
        shift = start % C
        trim = lambda a: a[:, start:, :]
        if shift:
            trim = lambda a: jnp.roll(a[:, start:, :], shift=shift, axis=1)
        k, v = trim(kv["k"]), trim(kv["v"])
        kpos = (start + jnp.arange(C)).astype(jnp.int32)
        if shift:
            kpos = jnp.roll(kpos, shift=shift, axis=0)
    else:
        pad = lambda a: jnp.pad(a, ((0, 0), (0, C - S), (0, 0)))
        k, v = pad(kv["k"]), pad(kv["v"])
        kpos = jnp.concatenate([jnp.arange(S, dtype=jnp.int32),
                                jnp.full((C - S,), -1, jnp.int32)])
    return {"k": k, "v": v, "pos": kpos, "next": jnp.asarray(S, jnp.int32)}


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2 multi-head latent attention)
# ---------------------------------------------------------------------------

def mla_forward(params, x, positions, cfg: AttnConfig):
    """Full-sequence MLA (train / prefill): per-block KV expansion.

    Returns (out, latent (B, S, kv_lora + rope_hd)) — the latent stream is the
    decode cache content.
    """
    B, S, _ = x.shape
    h, dn = cfg.num_heads, cfg.head_dim
    dr, dv = cfg.rope_head_dim, (cfg.v_head_dim or cfg.head_dim)
    kvl = cfg.kv_lora

    ql = rms_norm(x @ params["wq_a"], params["q_norm"])
    qall = (ql @ params["wq_b"]).reshape(B, S, h, dn + dr)
    q_nope, q_pe = qall[..., :dn], qall[..., dn:]
    q_pe = apply_rope(q_pe, positions, cfg.rope_theta)

    dkv = x @ params["w_dkv"]                       # (B, S, kvl + dr)
    c_kv = rms_norm(dkv[..., :kvl], params["kv_norm"])
    k_pe = apply_rope(dkv[..., None, kvl:], positions, cfg.rope_theta)[:, :, 0]

    def expand(lat_b):
        c, pe = lat_b
        kb = c.shape[1]
        k_nope = (c @ params["w_uk"]).reshape(B, kb, h, dn)
        v = (c @ params["w_uv"]).reshape(B, kb, h, dv)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(pe[:, :, None, :], (B, kb, h, dr))], -1)
        return k, v

    q_full = jnp.concatenate([q_nope, q_pe], axis=-1)
    qb = min(cfg.q_block, S)
    kb = min(cfg.kv_block, S)
    if cfg.causal_schedule == "banded" and cfg.causal and S >= 4 * qb:
        out = banded_blockwise(
            q_full, (c_kv, k_pe), expand, window=cfg.window,
            q_offset=positions[0], kv_positions=positions,
            q_block=qb, kv_block=kb, scale=1.0 / math.sqrt(dn + dr))
    else:
        out = blockwise_attention(
            q_full, (c_kv, k_pe), expand, causal=cfg.causal, window=cfg.window,
            q_offset=positions[0], kv_positions=positions,
            q_block=qb, kv_block=kb, scale=1.0 / math.sqrt(dn + dr))
    out = out.astype(x.dtype).reshape(B, S, h * dv)
    return out @ params["wo"], jnp.concatenate([c_kv, k_pe], axis=-1)


def mla_decode(params, x, cache, cfg: AttnConfig):
    """Absorbed-form MLA decode: attention in latent space; cache is
    (kv_lora + rope_hd) floats per token (the MLA memory win)."""
    B, S, _ = x.shape
    h, dn = cfg.num_heads, cfg.head_dim
    dr, dv = cfg.rope_head_dim, (cfg.v_head_dim or cfg.head_dim)
    kvl = cfg.kv_lora
    pos = cache["next"]
    positions = pos[None] + jnp.arange(S)

    ql = rms_norm(x @ params["wq_a"], params["q_norm"])
    qall = (ql @ params["wq_b"]).reshape(B, S, h, dn + dr)
    q_nope, q_pe = qall[..., :dn], qall[..., dn:]
    q_pe = apply_rope(q_pe, positions, cfg.rope_theta)

    dkv = x @ params["w_dkv"]
    c_kv = rms_norm(dkv[..., :kvl], params["kv_norm"])
    k_pe = apply_rope(dkv[..., None, kvl:], positions, cfg.rope_theta)[:, :, 0]
    latent_new = jnp.concatenate([c_kv, k_pe], axis=-1)

    C = cache["latent"].shape[1]
    slot = pos % C
    lat = jax.lax.dynamic_update_slice(cache["latent"], latent_new, (0, slot, 0))
    kpos = jax.lax.dynamic_update_slice(cache["pos"], positions.astype(jnp.int32),
                                        (slot,))

    # absorb W_uk into q: q_eff[b,s,h,kvl] = q_nope . W_uk_h^T
    w_uk = params["w_uk"].reshape(kvl, h, dn)
    q_eff = jnp.einsum("bshd,khd->bshk", qf(q_nope), qf(w_uk))  # k = kvl
    s_lat = jnp.einsum("bshk,bck->bhsc", q_eff, qf(lat[..., :kvl]))
    s_pe = jnp.einsum("bshd,bcd->bhsc", qf(q_pe), qf(lat[..., kvl:]))
    s = (s_lat + s_pe) / math.sqrt(dn + dr)

    valid = (kpos[None, :] >= 0) & (positions[:, None] >= kpos[None, :])
    s = jnp.where(valid[None, None, :, :], s, _MASK_VALUE)
    p = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bhsc,bck->bshk", p, qf(lat[..., :kvl]))   # latent ctx
    w_uv = params["w_uv"].reshape(kvl, h, dv)
    out = jnp.einsum("bshk,khd->bshd", ctx, qf(w_uv))
    out = out.astype(x.dtype).reshape(B, S, h * dv)
    new_cache = {"latent": lat, "pos": kpos, "next": pos + S}
    return out @ params["wo"], new_cache


def mla_init_cache(cfg: AttnConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    return {"latent": jnp.zeros((batch, max_len, cfg.kv_lora + cfg.rope_head_dim),
                                dtype),
            "pos": jnp.full((max_len,), -1, jnp.int32),
            "next": jnp.zeros((), jnp.int32)}


__all__ = [
    "AttnConfig", "attn_layout", "blockwise_attention",
    "gqa_forward", "gqa_decode", "gqa_init_cache", "gqa_prefill_cache",
    "mla_forward", "mla_decode", "mla_init_cache",
]
