"""Generic transformer LM assembly (dense / GQA / MQA / SWA / MoE / MLA /
encoder-only / external-embedding), with scan-over-layers + remat.

One `ModelConfig` covers 8 of the 10 assigned architectures; the Griffin and
xLSTM stacks live in hybrid.py and plug into the same Model API:

    model.init(key)                       -> params pytree
    model.layout()                        -> param layout table (shapes+specs)
    model.loss(params, batch)             -> scalar (train_step objective)
    model.prefill(params, batch)          -> (last-position logits, cache)
    model.decode_step(params, tokens, cache) -> (logits, cache)
    model.init_cache(batch, max_len)      -> cache pytree (ring buffers)

Layers of one kind are stacked and driven by `lax.scan` (constant compile time
at 60 layers — required for the 1-core dry-run and good practice at 1000-node
scale), each wrapped in `jax.checkpoint` with a configurable remat policy.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any

import jax
import jax.numpy as jnp

from .common import (Layout, init_params, abstract_params, param_specs,
                     param_count, rms_norm, glu_mlp, glu_mlp_layout,
                     mlp, mlp_layout, chunked_cross_entropy)
from .attention import (AttnConfig, attn_layout, gqa_forward, gqa_decode,
                        gqa_init_cache, gqa_prefill_cache, mla_forward,
                        mla_decode, mla_init_cache)
from .moe import MoEConfig, moe_layout, moe_forward


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # transformer | griffin | xlstm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int | None = None      # default d_model // num_heads
    act: str = "silu"
    causal: bool = True
    encoder_only: bool = False       # hubert: bidirectional, no decode
    window: int | None = None        # sliding-window attention
    rope_theta: float = 10000.0
    moe: MoEConfig | None = None
    mla: dict | None = None          # q_lora/kv_lora/rope_head_dim/v_head_dim
    embed_inputs: bool = True        # False: batch supplies "embeds" directly
    num_image_tokens: int = 0        # llava: prepended patch embeddings
    embed_scale: bool = False        # gemma: scale embeddings by sqrt(d)
    mlp_glu: bool = True             # False: plain 2-matrix MLP (hubert)
    use_rope: bool = True            # False: frontend supplies positions (hubert)
    tie_embeddings: bool = True
    scan_layers: bool = True
    remat_policy: str = "full"       # none | dots | full
    dtype: Any = jnp.bfloat16
    # griffin/xlstm extras
    block_pattern: tuple = ()
    d_rnn: int = 0
    conv_width: int = 4
    # attention blocking
    q_block: int = 512
    kv_block: int = 1024
    loss_chunk: int = 512
    # sub-quadratic flag for the 500k cells (set per arch in configs/)
    subquadratic: bool = False
    causal_schedule: str = "full"    # "banded": §Perf causal band skipping

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    def attn_config(self) -> AttnConfig:
        mla = self.mla or {}
        return AttnConfig(
            d_model=self.d_model, num_heads=self.num_heads,
            num_kv_heads=self.num_kv_heads, head_dim=self.hd,
            causal=self.causal and not self.encoder_only,
            window=self.window, rope_theta=self.rope_theta,
            use_rope=self.use_rope,
            q_block=self.q_block, kv_block=self.kv_block,
            q_lora=mla.get("q_lora"), kv_lora=mla.get("kv_lora"),
            rope_head_dim=mla.get("rope_head_dim", 64),
            v_head_dim=mla.get("v_head_dim"),
            causal_schedule=self.causal_schedule)


def _remat(fn, policy: str):
    if policy == "none":
        return fn
    pol = {"full": None,
           "dots": jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
           }[policy]
    return jax.checkpoint(fn, policy=pol)


# ---------------------------------------------------------------------------
# Layouts
# ---------------------------------------------------------------------------

def layer_layout(cfg: ModelConfig) -> Layout:
    lay: Layout = {
        "ln_attn": ((cfg.d_model,), (None,), "zeros"),
        "attn": attn_layout(cfg.attn_config()),
        "ln_mlp": ((cfg.d_model,), (None,), "zeros"),
    }
    if cfg.moe is not None:
        lay["moe"] = moe_layout(cfg.d_model, cfg.moe)
    elif cfg.mlp_glu:
        lay["mlp"] = glu_mlp_layout(cfg.d_model, cfg.d_ff)
    else:
        lay["mlp"] = mlp_layout(cfg.d_model, cfg.d_ff)
    return lay


def _stack_layout(lay: Layout, n: int) -> Layout:
    return {k: (_stack_layout(v, n) if isinstance(v, dict)
                else ((n, *v[0]), (None, *v[1]), v[2]))
            for k, v in lay.items()}


def model_layout(cfg: ModelConfig) -> Layout:
    lay: Layout = {}
    if cfg.embed_inputs or cfg.num_image_tokens:
        lay["embed"] = ((cfg.vocab, cfg.d_model), ("vocab", "model_d"), "embed")
    per_layer = layer_layout(cfg)
    if cfg.scan_layers:
        lay["layers"] = _stack_layout(per_layer, cfg.num_layers)
    else:
        lay["layers"] = {f"l{i}": per_layer for i in range(cfg.num_layers)}
    lay["ln_out"] = ((cfg.d_model,), (None,), "zeros")
    if not cfg.tie_embeddings:
        lay["head"] = ((cfg.d_model, cfg.vocab), ("model_d", "vocab"), "normal")
    return lay


# ---------------------------------------------------------------------------
# Layer body
# ---------------------------------------------------------------------------

def _layer_fwd(cfg: ModelConfig, lp, x, positions):
    """Full-sequence layer. Returns (x', kv_for_cache, aux_loss)."""
    acfg = cfg.attn_config()
    h = rms_norm(x, lp["ln_attn"])
    if acfg.kv_lora is not None:
        attn_out, kv = mla_forward(lp["attn"], h, positions, acfg)
    else:
        attn_out, kv = gqa_forward(lp["attn"], h, positions, acfg)
    x = x + attn_out
    h = rms_norm(x, lp["ln_mlp"])
    if cfg.moe is not None:
        mlp_out, aux = moe_forward(lp["moe"], h, cfg.moe, act=cfg.act)
    elif cfg.mlp_glu:
        mlp_out, aux = glu_mlp(lp["mlp"], h, act=cfg.act), jnp.float32(0)
    else:
        mlp_out, aux = mlp(lp["mlp"], h, act=cfg.act), jnp.float32(0)
    return x + mlp_out, kv, aux


def _layer_decode(cfg: ModelConfig, lp, x, cache_l):
    acfg = cfg.attn_config()
    h = rms_norm(x, lp["ln_attn"])
    if acfg.kv_lora is not None:
        attn_out, cache_l = mla_decode(lp["attn"], h, cache_l, acfg)
    else:
        attn_out, cache_l = gqa_decode(lp["attn"], h, cache_l, acfg)
    x = x + attn_out
    h = rms_norm(x, lp["ln_mlp"])
    if cfg.moe is not None:
        mlp_out, _ = moe_forward(lp["moe"], h, cfg.moe, act=cfg.act)
    elif cfg.mlp_glu:
        mlp_out = glu_mlp(lp["mlp"], h, act=cfg.act)
    else:
        mlp_out = mlp(lp["mlp"], h, act=cfg.act)
    return x + mlp_out, cache_l


# ---------------------------------------------------------------------------
# Stacks
# ---------------------------------------------------------------------------

def _run_stack(cfg: ModelConfig, params, x, positions, collect_kv: bool):
    body = functools.partial(_layer_fwd, cfg)
    body = _remat(body, cfg.remat_policy)
    if cfg.scan_layers:
        def scan_body(carry, lp):
            h, aux = carry
            h, kv, a = body(lp, h, positions)
            return (h, aux + a), (kv if collect_kv else jnp.zeros((0,)))
        (x, aux), kvs = jax.lax.scan(scan_body, (x, jnp.float32(0)),
                                     params["layers"])
        return x, kvs, aux
    aux = jnp.float32(0)
    kvs = []
    for i in range(cfg.num_layers):
        x, kv, a = body(params["layers"][f"l{i}"], x, positions)
        aux += a
        if collect_kv:
            kvs.append(kv)
    return x, (jnp.stack(kvs) if collect_kv and kvs else None), aux


def _run_stack_decode(cfg: ModelConfig, params, x, cache):
    body = functools.partial(_layer_decode, cfg)
    if cfg.scan_layers:
        def scan_body(h, xs):
            lp, cl = xs
            h, cl = body(lp, h, cl)
            return h, cl
        x, cache = jax.lax.scan(scan_body, x, (params["layers"], cache))
        return x, cache
    new_cache = []
    for i in range(cfg.num_layers):
        x, cl = body(params["layers"][f"l{i}"], x, cache[i])
        new_cache.append(cl)
    return x, new_cache


# ---------------------------------------------------------------------------
# Model API
# ---------------------------------------------------------------------------

class TransformerLM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # -- params -------------------------------------------------------------
    def layout(self) -> Layout:
        return model_layout(self.cfg)

    def init(self, key):
        return init_params(key, self.layout(), self.cfg.dtype)

    def abstract_params(self):
        return abstract_params(self.layout(), self.cfg.dtype)

    def param_specs(self, rules):
        return param_specs(rules, self.layout())

    def param_count(self) -> int:
        return param_count(self.layout())

    def active_param_count(self) -> int:
        """Per-token active params (MoE: top_k + shared experts only)."""
        cfg = self.cfg
        total = param_count(self.layout())
        if cfg.moe is None:
            return total
        e = cfg.moe
        per_expert = 3 * cfg.d_model * e.d_ff_expert
        routed_all = cfg.num_layers * e.num_experts * per_expert
        routed_active = cfg.num_layers * e.top_k * per_expert
        return total - routed_all + routed_active

    # -- inputs -------------------------------------------------------------
    def _embed_tokens(self, params, batch):
        cfg = self.cfg
        if not cfg.embed_inputs and not cfg.num_image_tokens:
            return batch["embeds"].astype(cfg.dtype)
        x = params["embed"][batch["tokens"]]
        if cfg.num_image_tokens:
            img = batch["image_embeds"].astype(cfg.dtype)
            x = jnp.concatenate([img, x], axis=1)
        if cfg.embed_scale:
            x = x * jnp.asarray(math.sqrt(cfg.d_model), cfg.dtype)
        return x

    def _head(self, params):
        if self.cfg.tie_embeddings and "embed" in params:
            return params["embed"].T
        return params["head"]

    # -- training -----------------------------------------------------------
    def loss(self, params, batch):
        cfg = self.cfg
        x = self._embed_tokens(params, batch)
        B, S, _ = x.shape
        positions = jnp.arange(S)
        x, _, aux = _run_stack(cfg, params, x, positions, collect_kv=False)
        x = rms_norm(x, params["ln_out"])
        ce = chunked_cross_entropy(
            lambda l: l.astype(jnp.float32), x, self._head(params),
            batch["labels"], batch["mask"].astype(jnp.float32),
            chunk=min(cfg.loss_chunk, S))
        return ce + 0.01 * aux / max(cfg.num_layers, 1)

    # -- serving ------------------------------------------------------------
    def prefill(self, params, batch, max_len: int | None = None):
        cfg = self.cfg
        x = self._embed_tokens(params, batch)
        B, S, _ = x.shape
        max_len = max_len or S
        positions = jnp.arange(S)
        if cfg.encoder_only:
            # encoder "prefill" = full-sequence emissions (the alignment-head
            # input); encoders keep no autoregressive cache
            x, _, _ = _run_stack(cfg, params, x, positions, collect_kv=False)
            x = rms_norm(x, params["ln_out"])

            def emit(chunk):  # chunked head matmul: avoid (B, S, V) at once?
                return (chunk @ self._head(params)).astype(jnp.float32)
            logits = emit(x)
            return logits, None
        x, kvs, _ = _run_stack(cfg, params, x, positions, collect_kv=True)
        x = rms_norm(x, params["ln_out"])
        logits = (x[:, -1:, :] @ self._head(params)).astype(jnp.float32)
        acfg = cfg.attn_config()
        if acfg.kv_lora is not None:
            def mk(kv):
                C = max_len
                lat = jnp.pad(kv, ((0, 0), (0, C - S), (0, 0)))
                kpos = jnp.concatenate([positions.astype(jnp.int32),
                                        jnp.full((C - S,), -1, jnp.int32)])
                return {"latent": lat, "pos": kpos,
                        "next": jnp.asarray(S, jnp.int32)}
        else:
            def mk(kv):
                return gqa_prefill_cache(acfg, kv, positions, max_len)
        cache = jax.vmap(mk)(kvs) if cfg.scan_layers else [mk(kv) for kv in kvs]
        return logits, cache

    def decode_step(self, params, tokens, cache):
        cfg = self.cfg
        if cfg.encoder_only:
            raise ValueError(f"{cfg.name} is encoder-only: no decode step")
        x = params["embed"][tokens]
        if cfg.embed_scale:
            x = x * jnp.asarray(math.sqrt(cfg.d_model), cfg.dtype)
        x, cache = _run_stack_decode(cfg, params, x, cache)
        x = rms_norm(x, params["ln_out"])
        logits = (x @ self._head(params)).astype(jnp.float32)
        return logits, cache

    def init_cache(self, batch: int, max_len: int):
        cfg = self.cfg
        acfg = cfg.attn_config()
        if acfg.kv_lora is not None:
            one = mla_init_cache(acfg, batch, max_len, cfg.dtype)
        else:
            one = gqa_init_cache(acfg, batch, max_len, cfg.dtype)
        if cfg.scan_layers:
            return jax.tree_util.tree_map(
                lambda a: jnp.broadcast_to(a, (cfg.num_layers, *a.shape)), one)
        return [one for _ in range(cfg.num_layers)]

    def cache_specs(self, rules):
        """PartitionSpec tree matching init_cache (batch over data axis)."""
        cfg = self.cfg
        acfg = cfg.attn_config()
        lead = (None,) if cfg.scan_layers else ()
        kv_axis = "kv_heads" if (cfg.mla is None and cfg.num_kv_heads > 1) else None

        def spec(*ax):
            from jax.sharding import PartitionSpec as P
            names = lead + ax
            return P(*(rules.axis(a) if isinstance(a, str) else a for a in names))

        if acfg.kv_lora is not None:
            # MLA latent has no heads dim: shard the cache *sequence* over the
            # model axis instead (XLA handles the cross-shard softmax)
            one = {"latent": spec("batch", "heads", None),
                   "pos": spec("heads"), "next": spec()}
        else:
            one = {"k": spec("batch", None, kv_axis),
                   "v": spec("batch", None, kv_axis),
                   "pos": spec(None), "next": spec()}
        if cfg.scan_layers:
            return one
        return [one for _ in range(cfg.num_layers)]


__all__ = ["ModelConfig", "TransformerLM", "model_layout", "layer_layout"]
