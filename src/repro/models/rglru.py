"""RG-LRU recurrent block (Griffin / RecurrentGemma).

The recurrence is elementwise-diagonal and input-gated:
    r_t = sigmoid(x_t W_r + b_r)          (recurrence gate)
    i_t = sigmoid(x_t W_i + b_i)          (input gate)
    a_t = exp(-c * softplus(Lambda) * r_t)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Training/prefill evaluates it with `lax.associative_scan` over the combine
((a1,b1),(a2,b2)) -> (a1*a2, a2*b1 + b2) — O(log S) depth, which is what makes
the hybrid arch eligible for the 500k-token cells.  Decode carries (h, conv
tail) state — O(1) per step, no KV cache.

Block structure (Griffin recurrent block): two input branches
  y = W_out( GeLU(x W_gate) * RGLRU(conv1d_4(x W_x)) ).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .common import Layout

_C = 8.0


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    d_model: int
    d_rnn: int          # recurrence width (RecurrentGemma: d_rnn = d_model)
    conv_width: int = 4


def rglru_layout(cfg: RGLRUConfig) -> Layout:
    d, r = cfg.d_model, cfg.d_rnn
    return {
        "w_x": ((d, r), ("model_d", "ff"), "normal"),
        "w_gate": ((d, r), ("model_d", "ff"), "normal"),
        "conv_w": ((cfg.conv_width, r), (None, "ff"), "normal"),
        "conv_b": ((r,), ("ff",), "zeros"),
        "w_rg": ((r, r), ("ff", None), "normal"),
        "b_rg": ((r,), (None,), "zeros"),
        "w_ig": ((r, r), ("ff", None), "normal"),
        "b_ig": ((r,), (None,), "zeros"),
        "lam": ((r,), (None,), "rglru_a"),
        "w_out": ((r, d), ("ff", "model_d"), "normal"),
    }


def _causal_conv1d(x, w, b, state=None):
    """x: (B, S, R), w: (W, R) depthwise. state: (B, W-1, R) tail or None."""
    W = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(W))
    return out + b, xp[:, -(W - 1):, :]


def _gates(params, u):
    r = jax.nn.sigmoid(u @ params["w_rg"] + params["b_rg"]).astype(jnp.float32)
    i = jax.nn.sigmoid(u @ params["w_ig"] + params["b_ig"]).astype(jnp.float32)
    log_a = -_C * jax.nn.softplus(params["lam"]).astype(jnp.float32) * r
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * \
        (i * u.astype(jnp.float32))
    return a, gated


def rglru_scan(params, u):
    """Full-sequence RG-LRU via associative scan. u: (B, S, R) -> (B, S, R)."""
    a, b = _gates(params, u)

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    a_s, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h.astype(u.dtype), h[:, -1].astype(jnp.float32)


def rglru_step(params, u, h_prev):
    """One decode step. u: (B, 1, R), h_prev: (B, R) f32."""
    a, b = _gates(params, u)
    h = a[:, 0] * h_prev + b[:, 0]
    return h[:, None, :].astype(u.dtype), h


def block_forward(params, x, cfg: RGLRUConfig, state=None):
    """Griffin recurrent block. state: None (train/prefill from scratch) or
    {"h": (B,R) f32, "conv": (B,W-1,R)}. Returns (y, new_state)."""
    gate = jax.nn.gelu(x @ params["w_gate"], approximate=True)
    u = x @ params["w_x"]
    conv_state = None if state is None else state["conv"]
    u, conv_tail = _causal_conv1d(u, params["conv_w"], params["conv_b"],
                                  conv_state)
    if state is None or x.shape[1] > 1:
        h_seq, h_last = rglru_scan(params, u)
    else:
        h_seq, h_last = rglru_step(params, u, state["h"])
    y = (gate * h_seq) @ params["w_out"]
    return y, {"h": h_last, "conv": conv_tail}


def init_state(cfg: RGLRUConfig, batch: int, dtype=jnp.bfloat16):
    return {"h": jnp.zeros((batch, cfg.d_rnn), jnp.float32),
            "conv": jnp.zeros((batch, cfg.conv_width - 1, cfg.d_rnn), dtype)}


__all__ = ["RGLRUConfig", "rglru_layout", "block_forward", "init_state",
           "rglru_scan", "rglru_step"]
