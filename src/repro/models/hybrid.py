"""Hybrid/recurrent model stacks: Griffin (RecurrentGemma) and xLSTM.

Same Model API as TransformerLM.  Layer pattern is expressed as repeating
*units* that are scanned (RecurrentGemma: (rec, rec, local-attn) x 8 + 2 tail
rec layers for 26; xLSTM-350m: (mLSTM, sLSTM) x 12 for 24), so compile time
stays flat in depth while preserving the exact interleaving order.

Both families are sub-quadratic (recurrent state is O(1) in sequence length;
local attention caches only its window), which is why they carry the
long_500k decode cells.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .common import (Layout, init_params, abstract_params, param_specs,
                     param_count, rms_norm, glu_mlp, glu_mlp_layout,
                     chunked_cross_entropy)
from .attention import (attn_layout, gqa_forward, gqa_decode, gqa_init_cache,
                        gqa_prefill_cache)
from .transformer import ModelConfig, _remat
from . import rglru as rg
from . import xlstm as xl


def _stack(lay: Layout, n: int) -> Layout:
    return {k: (_stack(v, n) if isinstance(v, dict)
                else ((n, *v[0]), (None, *v[1]), v[2]))
            for k, v in lay.items()}


# ---------------------------------------------------------------------------
# Griffin / RecurrentGemma
# ---------------------------------------------------------------------------

class GriffinLM:
    """(rec, rec, local-attn) repeating pattern + GeGLU MLP per layer."""

    def __init__(self, cfg: ModelConfig):
        assert cfg.family == "griffin"
        self.cfg = cfg
        self.n_units, self.n_tail = divmod(cfg.num_layers, 3)
        self.rcfg = rg.RGLRUConfig(d_model=cfg.d_model,
                                   d_rnn=cfg.d_rnn or cfg.d_model,
                                   conv_width=cfg.conv_width)

    # -- layouts --------------------------------------------------------
    def _rec_layer(self) -> Layout:
        d = self.cfg.d_model
        return {"ln_mix": ((d,), (None,), "zeros"),
                "mix": rg.rglru_layout(self.rcfg),
                "ln_mlp": ((d,), (None,), "zeros"),
                "mlp": glu_mlp_layout(d, self.cfg.d_ff)}

    def _attn_layer(self) -> Layout:
        d = self.cfg.d_model
        return {"ln_mix": ((d,), (None,), "zeros"),
                "mix": attn_layout(self.cfg.attn_config()),
                "ln_mlp": ((d,), (None,), "zeros"),
                "mlp": glu_mlp_layout(d, self.cfg.d_ff)}

    def layout(self) -> Layout:
        cfg = self.cfg
        unit = {"rec1": self._rec_layer(), "rec2": self._rec_layer(),
                "attn": self._attn_layer()}
        lay: Layout = {
            "embed": ((cfg.vocab, cfg.d_model), ("vocab", "model_d"), "embed"),
            "units": _stack(unit, self.n_units),
            "ln_out": ((cfg.d_model,), (None,), "zeros"),
        }
        for i in range(self.n_tail):
            lay[f"tail{i}"] = self._rec_layer()
        return lay

    def init(self, key):
        return init_params(key, self.layout(), self.cfg.dtype)

    def abstract_params(self):
        return abstract_params(self.layout(), self.cfg.dtype)

    def param_specs(self, rules):
        return param_specs(rules, self.layout())

    def param_count(self) -> int:
        return param_count(self.layout())

    def active_param_count(self) -> int:
        return self.param_count()

    # -- blocks -----------------------------------------------------------
    def _rec_block(self, lp, x, state):
        y, st = rg.block_forward(lp["mix"], rms_norm(x, lp["ln_mix"]),
                                 self.rcfg, state)
        x = x + y
        return x + glu_mlp(lp["mlp"], rms_norm(x, lp["ln_mlp"]),
                           act=self.cfg.act), st

    def _attn_block_fwd(self, lp, x, positions):
        acfg = self.cfg.attn_config()
        y, kv = gqa_forward(lp["mix"], rms_norm(x, lp["ln_mix"]), positions, acfg)
        x = x + y
        return x + glu_mlp(lp["mlp"], rms_norm(x, lp["ln_mlp"]),
                           act=self.cfg.act), kv

    def _attn_block_dec(self, lp, x, cache):
        acfg = self.cfg.attn_config()
        y, cache = gqa_decode(lp["mix"], rms_norm(x, lp["ln_mix"]), cache, acfg)
        x = x + y
        return x + glu_mlp(lp["mlp"], rms_norm(x, lp["ln_mlp"]),
                           act=self.cfg.act), cache

    # -- forward ----------------------------------------------------------
    def _embed(self, params, tokens):
        x = params["embed"][tokens]
        return x * jnp.asarray(math.sqrt(self.cfg.d_model), self.cfg.dtype)

    def _stack_fwd(self, params, x, positions, collect: bool):
        cfg = self.cfg

        def unit_fwd(x, up):
            x, s1 = self._rec_block(up["rec1"], x, None)
            x, s2 = self._rec_block(up["rec2"], x, None)
            x, kv = self._attn_block_fwd(up["attn"], x, positions)
            out = (s1, s2, kv if collect else jnp.zeros((0,)))
            return x, out

        unit_fwd = _remat(unit_fwd, cfg.remat_policy)
        x, (s1s, s2s, kvs) = jax.lax.scan(unit_fwd, x, params["units"])
        tails = []
        for i in range(self.n_tail):
            x, st = self._rec_block(params[f"tail{i}"], x, None)
            tails.append(st)
        return x, (s1s, s2s, kvs, tails)

    def loss(self, params, batch):
        cfg = self.cfg
        x = self._embed(params, batch["tokens"])
        S = x.shape[1]
        x, _ = self._stack_fwd(params, x, jnp.arange(S), collect=False)
        x = rms_norm(x, params["ln_out"])
        return chunked_cross_entropy(
            lambda l: l.astype(jnp.float32), x, params["embed"].T,
            batch["labels"], batch["mask"].astype(jnp.float32),
            chunk=min(cfg.loss_chunk, S))

    def prefill(self, params, batch, max_len: int | None = None):
        cfg = self.cfg
        tokens = batch["tokens"]
        S = tokens.shape[1]
        max_len = max_len or S
        positions = jnp.arange(S)
        x = self._embed(params, tokens)
        x, (s1s, s2s, kvs, tails) = self._stack_fwd(params, x, positions,
                                                    collect=True)
        x = rms_norm(x, params["ln_out"])
        logits = (x[:, -1:, :] @ params["embed"].T).astype(jnp.float32)
        acfg = cfg.attn_config()
        attn_cache = jax.vmap(
            lambda kv: gqa_prefill_cache(acfg, kv, positions, max_len))(kvs)
        cache = {"rec1": s1s, "rec2": s2s, "attn": attn_cache, "tails": tails,
                 "next": jnp.asarray(S, jnp.int32)}
        return logits, cache

    def decode_step(self, params, tokens, cache):
        cfg = self.cfg
        x = self._embed(params, tokens)

        def unit_dec(x, xs):
            up, s1, s2, ac = xs
            x, s1 = self._rec_block(up["rec1"], x, s1)
            x, s2 = self._rec_block(up["rec2"], x, s2)
            x, ac = self._attn_block_dec(up["attn"], x, ac)
            return x, (s1, s2, ac)

        x, (s1s, s2s, acs) = jax.lax.scan(
            unit_dec, x, (params["units"], cache["rec1"], cache["rec2"],
                          cache["attn"]))
        tails = []
        for i in range(self.n_tail):
            x, st = self._rec_block(params[f"tail{i}"], x, cache["tails"][i])
            tails.append(st)
        x = rms_norm(x, params["ln_out"])
        logits = (x @ params["embed"].T).astype(jnp.float32)
        return logits, {"rec1": s1s, "rec2": s2s, "attn": acs, "tails": tails,
                        "next": cache["next"] + 1}

    def init_cache(self, batch: int, max_len: int):
        cfg = self.cfg
        acfg = cfg.attn_config()
        rec = rg.init_state(self.rcfg, batch, cfg.dtype)
        stack = lambda a: jnp.broadcast_to(a, (self.n_units, *a.shape))
        return {
            "rec1": jax.tree_util.tree_map(stack, rec),
            "rec2": jax.tree_util.tree_map(stack, rec),
            "attn": jax.tree_util.tree_map(
                stack, gqa_init_cache(acfg, batch, max_len, cfg.dtype)),
            "tails": [rg.init_state(self.rcfg, batch, cfg.dtype)
                      for _ in range(self.n_tail)],
            "next": jnp.zeros((), jnp.int32),
        }

    def cache_specs(self, rules):
        from jax.sharding import PartitionSpec as P
        b = rules.axis("batch")
        rec = {"h": P(None, b), "conv": P(None, b, None, None)}
        rec_tail = {"h": P(b), "conv": P(b, None, None)}
        return {
            "rec1": rec, "rec2": rec,
            "attn": {"k": P(None, b, None, None), "v": P(None, b, None, None),
                     "pos": P(None, None), "next": P(None)},
            "tails": [rec_tail for _ in range(self.n_tail)],
            "next": P(),
        }


# ---------------------------------------------------------------------------
# xLSTM
# ---------------------------------------------------------------------------

class XLSTMLM:
    """Alternating (mLSTM, sLSTM) units, scanned."""

    def __init__(self, cfg: ModelConfig):
        assert cfg.family == "xlstm"
        self.cfg = cfg
        assert cfg.num_layers % 2 == 0
        self.n_units = cfg.num_layers // 2
        self.xcfg = xl.XLSTMConfig(d_model=cfg.d_model,
                                   num_heads=cfg.num_heads,
                                   conv_width=cfg.conv_width)

    def layout(self) -> Layout:
        cfg = self.cfg
        d = cfg.d_model
        unit = {
            "ln_m": ((d,), (None,), "zeros"),
            "m": xl.mlstm_layout(self.xcfg),
            "ln_s": ((d,), (None,), "zeros"),
            "s": xl.slstm_layout(self.xcfg),
        }
        return {
            "embed": ((cfg.vocab, d), ("vocab", "model_d"), "embed"),
            "units": _stack(unit, self.n_units),
            "ln_out": ((d,), (None,), "zeros"),
        }

    def init(self, key):
        return init_params(key, self.layout(), self.cfg.dtype)

    def abstract_params(self):
        return abstract_params(self.layout(), self.cfg.dtype)

    def param_specs(self, rules):
        return param_specs(rules, self.layout())

    def param_count(self) -> int:
        return param_count(self.layout())

    def active_param_count(self) -> int:
        return self.param_count()

    def _unit(self, up, x, state):
        m_state = None if state is None else state["m"]
        s_state = None if state is None else state["s"]
        y, m_new = xl.mlstm_block(up["m"], rms_norm(x, up["ln_m"]), self.xcfg,
                                  m_state)
        x = x + y
        y, s_new = xl.slstm_block(up["s"], rms_norm(x, up["ln_s"]), self.xcfg,
                                  s_state)
        return x + y, {"m": m_new, "s": s_new}

    def loss(self, params, batch):
        cfg = self.cfg
        x = params["embed"][batch["tokens"]]
        S = x.shape[1]

        def body(x, up):
            x, _ = self._unit(up, x, None)
            return x, None

        body = _remat(body, cfg.remat_policy)
        x, _ = jax.lax.scan(body, x, params["units"])
        x = rms_norm(x, params["ln_out"])
        return chunked_cross_entropy(
            lambda l: l.astype(jnp.float32), x, params["embed"].T,
            batch["labels"], batch["mask"].astype(jnp.float32),
            chunk=min(cfg.loss_chunk, S))

    def prefill(self, params, batch, max_len: int | None = None):
        x = params["embed"][batch["tokens"]]
        S = x.shape[1]

        def body(x, up):
            x, st = self._unit(up, x, self._fresh_state(x.shape[0]))
            return x, st

        x, states = jax.lax.scan(body, x, params["units"])
        x = rms_norm(x, params["ln_out"])
        logits = (x[:, -1:, :] @ params["embed"].T).astype(jnp.float32)
        return logits, {"units": states, "next": jnp.asarray(S, jnp.int32)}

    def _fresh_state(self, batch: int):
        cfg = self.cfg
        hd = cfg.d_model * 2 // cfg.num_heads  # mLSTM runs at 2x width
        return {
            "m": {"rec": xl.init_mlstm_state(batch, cfg.num_heads, hd),
                  "conv": jnp.zeros((batch, self.xcfg.conv_width - 1,
                                     cfg.d_model * 2), cfg.dtype)},
            "s": {"rec": xl.init_slstm_state(batch, cfg.d_model),
                  "conv": jnp.zeros((batch, self.xcfg.conv_width - 1,
                                     cfg.d_model), cfg.dtype)},
        }

    def decode_step(self, params, tokens, cache):
        x = params["embed"][tokens]

        def body(x, xs):
            up, st = xs
            x, st = self._unit(up, x, st)
            return x, st

        x, states = jax.lax.scan(body, x, (params["units"], cache["units"]))
        x = rms_norm(x, params["ln_out"])
        logits = (x @ params["embed"].T).astype(jnp.float32)
        return logits, {"units": states, "next": cache["next"] + 1}

    def init_cache(self, batch: int, max_len: int):
        one = self._fresh_state(batch)
        stack = lambda a: jnp.broadcast_to(a, (self.n_units, *a.shape))
        return {"units": jax.tree_util.tree_map(stack, one),
                "next": jnp.zeros((), jnp.int32)}

    def cache_specs(self, rules):
        from jax.sharding import PartitionSpec as P
        b = rules.axis("batch")
        one = {
            "m": {"rec": {"C": P(None, b), "n": P(None, b), "m": P(None, b)},
                  "conv": P(None, b, None, None)},
            "s": {"rec": {"c": P(None, b), "n": P(None, b), "m": P(None, b),
                          "h": P(None, b)},
                  "conv": P(None, b, None, None)},
        }
        return {"units": one, "next": P()}


__all__ = ["GriffinLM", "XLSTMLM"]
