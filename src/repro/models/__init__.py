"""Pure-JAX model zoo (no flax): transformers, Griffin, xLSTM."""

from .transformer import ModelConfig, TransformerLM
from .hybrid import GriffinLM, XLSTMLM
from .moe import MoEConfig
from .registry import build_model

__all__ = ["ModelConfig", "TransformerLM", "GriffinLM", "XLSTMLM",
           "MoEConfig", "build_model"]
