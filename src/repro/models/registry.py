"""Model registry: family string -> Model class; built from ModelConfig."""

from __future__ import annotations

from .transformer import ModelConfig, TransformerLM
from .hybrid import GriffinLM, XLSTMLM

_FAMILIES = {
    "transformer": TransformerLM,
    "griffin": GriffinLM,
    "xlstm": XLSTMLM,
}


def build_model(cfg: ModelConfig):
    try:
        cls = _FAMILIES[cfg.family]
    except KeyError:
        raise ValueError(f"unknown family {cfg.family!r}: {list(_FAMILIES)}")
    return cls(cfg)


__all__ = ["build_model"]
