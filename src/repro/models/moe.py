"""Mixture-of-Experts layer: top-k routing, capacity-bounded sort dispatch, EP.

Dispatch strategy (MaxText-style, XLA-SPMD friendly):
  1. router logits -> top-k expert ids + normalised probs per token;
  2. expanded assignments (tokens*k) are ranked within their expert via a
     one-hot cumsum; assignments beyond capacity C = tokens*k*cf/E are dropped;
  3. tokens scatter into a dense (E, C, d) buffer, experts run as one batched
     einsum (E sharded over the "model" axis = expert parallelism), and
     results gather-combine back weighted by router probs.

Compiled FLOPs are exactly cf * active-FLOPs (capacity_factor defaults to 1.0
so the roofline MODEL_FLOPS/HLO_FLOPs ratio stays interpretable).  The scatter/
gather across the (data -> model) axes is what shows up as all-to-all traffic
in the dry-run collective analysis.

Shared experts (DeepSeek-V2) run densely alongside the routed ones.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .common import Layout, act_fn


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared: int = 0
    capacity_factor: float = 1.0
    router_dtype: str = "float32"
    num_groups: int = 1      # >1: group-local routing (rank/capacity per
                             # token group; removes the global-prefix and
                             # cross-shard dispatch collectives)


def moe_layout(d: int, cfg: MoEConfig) -> Layout:
    lay: Layout = {
        "router": ((d, cfg.num_experts), ("model_d", None), "normal"),
        "wg": ((cfg.num_experts, d, cfg.d_ff_expert),
               ("experts", "model_d", "expert_ff"), "normal"),
        "wi": ((cfg.num_experts, d, cfg.d_ff_expert),
               ("experts", "model_d", "expert_ff"), "normal"),
        "wo": ((cfg.num_experts, cfg.d_ff_expert, d),
               ("experts", "expert_ff", "model_d"), "normal"),
    }
    if cfg.num_shared:
        f = cfg.d_ff_expert * cfg.num_shared
        lay["shared"] = {
            "wg": ((d, f), ("model_d", "ff"), "normal"),
            "wi": ((d, f), ("model_d", "ff"), "normal"),
            "wo": ((f, d), ("ff", "model_d"), "normal"),
        }
    return lay


def moe_forward(params, x, cfg: MoEConfig, act: str = "silu"):
    """x: (B, S, D) -> (B, S, D), plus aux load-balance loss.

    With num_groups > 1, routing ranks/capacities are computed per contiguous
    token group (groups align with the data-sharded batch): the rank cumsum
    and the dispatch scatter stay shard-local, trading a little capacity
    fragmentation for the removal of all cross-shard routing collectives."""
    B, S, D = x.shape
    G = cfg.num_groups
    if G > 1:
        assert (B * S) % G == 0, (B, S, G)
        xg = x.reshape(G, B * S // G, D)
        out, aux = jax.vmap(
            lambda xs: _moe_dense(params, xs[None], cfg, act))(xg)
        return out.reshape(B, S, D), jnp.mean(aux)
    return _moe_dense(params, x, cfg, act)


def _moe_dense(params, x, cfg: MoEConfig, act: str = "silu"):
    B, S, D = x.shape
    N = B * S
    E, K = cfg.num_experts, cfg.top_k
    C = max(1, int(N * K * cfg.capacity_factor / E))

    xt = x.reshape(N, D)
    rl = (xt.astype(cfg.router_dtype) @ params["router"].astype(cfg.router_dtype))
    probs = jax.nn.softmax(rl, axis=-1)                     # (N, E)
    top_p, top_e = jax.lax.top_k(probs, K)                  # (N, K)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)  # renormalise

    # load-balance aux loss (Switch-style)
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(top_e[:, 0], E), axis=0)
    aux = E * jnp.sum(me * ce)

    # rank within expert: position of each (token, slot) among same-expert picks
    flat_e = top_e.reshape(N * K)                           # expanded assignments
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)     # (N*K, E)
    ranks = (jnp.cumsum(onehot, axis=0) - onehot)           # exclusive prefix
    rank = jnp.take_along_axis(ranks, flat_e[:, None], axis=1)[:, 0]
    keep = rank < C

    # scatter tokens into (E, C, D)
    tok_idx = jnp.repeat(jnp.arange(N), K)
    slot = jnp.where(keep, rank, C)                         # C = overflow bin
    buf = jnp.zeros((E, C + 1, D), xt.dtype)
    buf = buf.at[flat_e, slot].set(xt[tok_idx], mode="drop")
    buf = buf[:, :C, :]

    # expert FFN: batched over E (sharded over the model axis)
    g = act_fn(act)(jnp.einsum("ecd,edf->ecf", buf, params["wg"]))
    h = g * jnp.einsum("ecd,edf->ecf", buf, params["wi"])
    y = jnp.einsum("ecf,efd->ecd", h, params["wo"])          # (E, C, D)

    # combine: gather each kept assignment's output, weight by router prob
    y_flat = jnp.pad(y, ((0, 0), (0, 1), (0, 0)))            # restore overflow bin
    out_exp = y_flat[flat_e, slot]                           # (N*K, D)
    w = jnp.where(keep, top_p.reshape(N * K), 0.0)
    out = jnp.zeros((N, D), jnp.float32)
    out = out.at[tok_idx].add(out_exp.astype(jnp.float32) * w[:, None])

    if cfg.num_shared:
        sp = params["shared"]
        sg = act_fn(act)(xt @ sp["wg"])
        out = out + ((sg * (xt @ sp["wi"])) @ sp["wo"]).astype(jnp.float32)

    return out.astype(x.dtype).reshape(B, S, D), aux


def moe_layout_groups(*args, **kw):  # back-compat alias
    return moe_layout(*args, **kw)


__all__ = ["MoEConfig", "moe_layout", "moe_forward"]
