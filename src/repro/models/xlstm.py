"""xLSTM blocks (sLSTM + mLSTM), per Beck et al. 2024 (arXiv:2405.04517).

* mLSTM: matrix memory C_t (hd x hd) per head with exponential gating; the
  query reads an associative retrieval.  Recurrent (decode) form carries
  (C, n, m); training uses the parallel quadratic form (attention-like with
  log-gate decay matrix D) evaluated blockwise — sub-quadratic in memory via
  the same online pattern as attention, here chunked with a stabilised
  cumulative-gate formulation.
* sLSTM: scalar memory per unit with exponential gating; inherently sequential
  -> `lax.scan` (the paper's sLSTM has no parallel form).

Simplifications recorded in DESIGN.md: block-diagonal projections and GroupNorm
are replaced by per-head RMS normalisation; causal conv1d front-ends kept.
Block pattern (xlstm-350m config): alternating mLSTM/sLSTM at ratio 1:1.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from .common import Layout, rms_norm

# Floor for the exponential-gating stabiliser m: the normaliser is
# max(|n|, exp(-m)), so m below ~-88 overflows exp(-m) to f32 inf and the
# backward pass hits 0*inf = nan.  Every value the floor touches is already
# ~exp(-80) in the output, so clamping is invisible at f32 precision.
_M_FLOOR = -80.0


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    d_model: int
    num_heads: int
    proj_factor_m: float = 2.0   # mLSTM up-projection
    proj_factor_s: float = 4.0 / 3.0
    conv_width: int = 4


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_layout(cfg: XLSTMConfig) -> Layout:
    d = cfg.d_model
    dp = int(d * cfg.proj_factor_m)
    return {
        "w_up": ((d, 2 * dp), ("model_d", "ff"), "normal"),
        "conv_w": ((cfg.conv_width, dp), (None, "ff"), "normal"),
        "conv_b": ((dp,), ("ff",), "zeros"),
        "wq": ((dp, dp), ("ff", None), "normal"),
        "wk": ((dp, dp), ("ff", None), "normal"),
        "wv": ((dp, dp), ("ff", None), "normal"),
        "w_if": ((dp, 2 * cfg.num_heads), ("ff", None), "normal"),
        "b_if": ((2 * cfg.num_heads,), (None,), "zeros"),
        "norm": ((dp,), ("ff",), "zeros"),
        "w_down": ((dp, d), ("ff", "model_d"), "normal"),
    }


def _heads(x, h):
    B, S, D = x.shape
    return x.reshape(B, S, h, D // h)


def mlstm_parallel(q, k, v, log_i, log_f):
    """Stabilised parallel mLSTM (quadratic form).

    q,k,v: (B, S, H, hd); log_i/log_f: (B, S, H). Returns (B, S, H, hd).
    D[t,s] = exp(cumF[t] - cumF[s] + log_i[s]) for s <= t, stabilised by the
    running row max (paper eq. 15-19).
    """
    B, S, H, hd = q.shape
    cf = jnp.cumsum(log_f, axis=1)                        # (B, S, H)
    lm = cf[:, :, None, :] - cf[:, None, :, :]            # (B, T, S, H) t>=s
    lg = lm + log_i[:, None, :, :]                        # + log i_s
    tri = jnp.tril(jnp.ones((S, S), bool))
    # flashlint: disable=FL007(causal attention mask in the encoder, not a decode allowed-set)
    lg = jnp.where(tri[None, :, :, None], lg, -jnp.inf)
    m = jnp.maximum(jnp.max(lg, axis=2, keepdims=True), _M_FLOOR)
    dmat = jnp.exp(lg - m)                                # (B, T, S, H)
    s = jnp.einsum("bthd,bshd->btsh", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(hd)
    c = s * dmat
    n = jnp.maximum(jnp.abs(jnp.sum(c, axis=2)), jnp.exp(-m[:, :, 0]))  # (B,T,H)
    out = jnp.einsum("btsh,bshd->bthd", c, v.astype(jnp.float32))
    return out / n[..., None]


def mlstm_chunked(q, k, v, log_i, log_f, chunk: int = 256):
    """Blockwise evaluation of the parallel form (bounds the (S, S) matrix to
    (chunk, S) slabs; exact, not an approximation)."""
    B, S, H, hd = q.shape
    if S <= chunk:
        return mlstm_parallel(q, k, v, log_i, log_f)
    n = S // chunk
    cf = jnp.cumsum(log_f, axis=1)

    def body(_, ti):
        t0 = ti * chunk
        qt = jax.lax.dynamic_slice_in_dim(q, t0, chunk, 1)
        cft = jax.lax.dynamic_slice_in_dim(cf, t0, chunk, 1)
        lm = cft[:, :, None, :] - cf[:, None, :, :]           # (B, c, S, H)
        lg = lm + log_i[:, None, :, :]
        tpos = t0 + jnp.arange(chunk)
        mask = tpos[:, None] >= jnp.arange(S)[None, :]
        # flashlint: disable=FL007(chunked causal attention mask in the encoder, not a decode allowed-set)
        lg = jnp.where(mask[None, :, :, None], lg, -jnp.inf)
        m = jnp.maximum(jnp.max(lg, axis=2, keepdims=True), _M_FLOOR)
        dmat = jnp.exp(lg - m)
        s = jnp.einsum("bthd,bshd->btsh", qt.astype(jnp.float32),
                       k.astype(jnp.float32)) / math.sqrt(hd)
        c = s * dmat
        nrm = jnp.maximum(jnp.abs(jnp.sum(c, axis=2)), jnp.exp(-m[:, :, 0]))
        out = jnp.einsum("btsh,bshd->bthd", c, v.astype(jnp.float32))
        return None, out / nrm[..., None]

    body = jax.checkpoint(body)  # (B, c, S, H) slabs recomputed in backward
    _, outs = jax.lax.scan(body, None, jnp.arange(n))
    return outs.swapaxes(0, 1).reshape(B, S, H, hd)


def mlstm_step(q, k, v, log_i, log_f, state):
    """Recurrent decode step. state: dict(C (B,H,hd,hd), n (B,H,hd), m (B,H))."""
    B, S, H, hd = q.shape  # S == 1
    qt, kt, vt = (x[:, 0].astype(jnp.float32) for x in (q, k, v))
    li, lf = log_i[:, 0], log_f[:, 0]                     # (B, H)
    m_new = jnp.maximum(jnp.maximum(lf + state["m"], li), _M_FLOOR)
    fi = jnp.exp(lf + state["m"] - m_new)[..., None]
    ii = jnp.exp(li - m_new)[..., None]
    kv = kt[..., :, None] * vt[..., None, :] / math.sqrt(hd)  # (B,H,hd,hd)
    C = fi[..., None] * state["C"] + ii[..., None] * kv
    n = fi * state["n"] + ii * kt
    num = jnp.einsum("bhd,bhde->bhe", qt, C)
    den = jnp.maximum(jnp.abs(jnp.sum(qt * n, -1)), jnp.exp(-m_new))
    out = (num / den[..., None])[:, None]                 # (B,1,H,hd)
    return out, {"C": C, "n": n, "m": m_new}


def mlstm_block(params, x, cfg: XLSTMConfig, state=None):
    """Pre-up-projected mLSTM block. Returns (y, new_state)."""
    from .rglru import _causal_conv1d
    B, S, _ = x.shape
    H = cfg.num_heads
    up = x @ params["w_up"]
    u, z = jnp.split(up, 2, axis=-1)                      # branch + gate
    conv_state = None if state is None else state["conv"]
    uc, conv_tail = _causal_conv1d(u, params["conv_w"], params["conv_b"],
                                   conv_state)
    uc = jax.nn.silu(uc)
    q = _heads(uc @ params["wq"], H)
    k = _heads(uc @ params["wk"], H)
    v = _heads(u @ params["wv"], H)
    gates = (uc @ params["w_if"] + params["b_if"]).astype(jnp.float32)
    log_i, log_f = gates[..., :H], jax.nn.log_sigmoid(gates[..., H:])

    if state is None or S > 1:
        h = mlstm_chunked(q, k, v, log_i, log_f)
        mst = _mlstm_final_state(q, k, v, log_i, log_f)
    else:
        h, mst = mlstm_step(q, k, v, log_i, log_f, state["rec"])
    hp = h.reshape(B, S, -1).astype(x.dtype)
    hn = rms_norm(hp, params["norm"]) * jax.nn.silu(z)
    y = hn @ params["w_down"]
    return y, {"rec": mst, "conv": conv_tail}


def _mlstm_final_state(q, k, v, log_i, log_f):
    """Recurrent state after a full prefill (scanned; only used at prefill->
    decode handoff, O(S) sequential but off the training path)."""
    B, S, H, hd = q.shape

    def body(st, xs):
        qt, kt, vt, li, lf = xs
        _, st = mlstm_step(qt[:, None], kt[:, None], vt[:, None],
                           li[:, None], lf[:, None], st)
        return st, None

    init = init_mlstm_state(B, H, hd)
    xs = (q.swapaxes(0, 1), k.swapaxes(0, 1), v.swapaxes(0, 1),
          log_i.swapaxes(0, 1), log_f.swapaxes(0, 1))
    st, _ = jax.lax.scan(body, init, xs)
    return st


def init_mlstm_state(batch: int, H: int, hd: int):
    return {"C": jnp.zeros((batch, H, hd, hd), jnp.float32),
            "n": jnp.zeros((batch, H, hd), jnp.float32),
            "m": jnp.full((batch, H), -1e30, jnp.float32)}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_layout(cfg: XLSTMConfig) -> Layout:
    d = cfg.d_model
    # round the 4/3 up-projection to a lane/TP-friendly multiple of 128
    dp = ((int(d * cfg.proj_factor_s) + 127) // 128) * 128
    return {
        "conv_w": ((cfg.conv_width, d), (None, None), "normal"),
        "conv_b": ((d,), (None,), "zeros"),
        "w_gates": ((d, 4 * d), ("model_d", "ff"), "normal"),
        "r_gates": ((d, 4 * d), (None, "ff"), "normal"),
        "b_gates": ((4 * d,), ("ff",), "zeros"),
        "norm": ((d,), (None,), "zeros"),
        "w_up": ((d, 2 * dp), ("model_d", "ff"), "normal"),
        "w_down": ((dp, d), ("ff", "model_d"), "normal"),
    }


def slstm_scan(params, x, state):
    """sLSTM over a sequence. x: (B, S, D). state: dict(c,n,m,h) each (B, D)."""
    B, S, D = x.shape

    def step(st, xt):
        zall = xt @ params["w_gates"] + st["h"].astype(xt.dtype) @ params["r_gates"] \
            + params["b_gates"]
        z, i, f, o = jnp.split(zall.astype(jnp.float32), 4, axis=-1)
        li = i
        lf = jax.nn.log_sigmoid(f)
        m_new = jnp.maximum(lf + st["m"], li)
        ii = jnp.exp(li - m_new)
        fi = jnp.exp(lf + st["m"] - m_new)
        c = fi * st["c"] + ii * jnp.tanh(z)
        n = fi * st["n"] + ii
        h = jax.nn.sigmoid(o) * c / jnp.maximum(n, 1.0)
        return {"c": c, "n": n, "m": m_new, "h": h}, h

    st, hs = jax.lax.scan(step, state, x.swapaxes(0, 1))
    return hs.swapaxes(0, 1).astype(x.dtype), st


def slstm_block(params, x, cfg: XLSTMConfig, state=None):
    from .rglru import _causal_conv1d
    B, S, D = x.shape
    conv_state = None if state is None else state["conv"]
    xc, conv_tail = _causal_conv1d(x, params["conv_w"], params["conv_b"],
                                   conv_state)
    xc = jax.nn.silu(xc)
    rec = init_slstm_state(B, D) if state is None else state["rec"]
    h, rec = slstm_scan(params, xc, rec)
    h = rms_norm(h, params["norm"])
    up = h @ params["w_up"]
    a, b = jnp.split(up, 2, axis=-1)
    y = (jax.nn.gelu(a, approximate=True) * b) @ params["w_down"]
    return y, {"rec": rec, "conv": conv_tail}


def init_slstm_state(batch: int, d: int):
    return {"c": jnp.zeros((batch, d), jnp.float32),
            "n": jnp.zeros((batch, d), jnp.float32),
            "m": jnp.full((batch, d), -1e30, jnp.float32),
            "h": jnp.zeros((batch, d), jnp.float32)}


__all__ = [
    "XLSTMConfig", "mlstm_layout", "slstm_layout", "mlstm_block", "slstm_block",
    "init_mlstm_state", "init_slstm_state", "mlstm_parallel", "mlstm_chunked",
    "mlstm_step", "slstm_scan",
]
