"""granite-8b [dense]: 36L d=4096 32H (GQA kv=8) d_ff=14336 vocab=49152.
Llama-arch, code model [arXiv:2405.04324; hf]. Full attention -> long_500k skipped."""

from repro.models.transformer import ModelConfig
from .base import lm_input_specs

CONFIG = ModelConfig(
    name="granite-8b", family="transformer",
    num_layers=36, d_model=4096, num_heads=32, num_kv_heads=8, head_dim=128,
    d_ff=14336, vocab=49152, act="silu", rope_theta=10000.0,
    tie_embeddings=False,
)

SMOKE = ModelConfig(
    name="granite-smoke", family="transformer",
    num_layers=3, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=160, vocab=256, act="silu", tie_embeddings=False,
    q_block=8, kv_block=8, loss_chunk=8,
)

SKIPS = {"long_500k": "pure full attention (no sub-quadratic path)"}


def input_specs(shape: str, multi_pod: bool = False):
    return lm_input_specs(CONFIG, shape, multi_pod, SKIPS)
