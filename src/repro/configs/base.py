"""Shared shape/spec machinery for the assigned architecture configs.

Every arch module exposes:
    CONFIG  -- the exact published configuration (ModelConfig)
    SMOKE   -- a reduced same-family config for CPU smoke tests
    SKIPS   -- {shape_name: reason} cells excluded per the assignment rules
    input_specs(shape, multi_pod) -> InputSpec | None  (None = skipped cell)

The four LM shapes (seq_len x global_batch):
    train_4k     4,096 x 256   -> train_step
    prefill_32k  32,768 x 32   -> prefill
    decode_32k   32,768 x 128  -> serve_step (1 new token, 32k KV cache)
    long_500k    524,288 x 1   -> serve_step (1 new token, 500k context)
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import build_model
from repro.models.transformer import ModelConfig

SHAPES: dict[str, tuple[str, int, int]] = {
    "train_4k": ("train", 4_096, 256),
    "prefill_32k": ("prefill", 32_768, 32),
    "decode_32k": ("decode", 32_768, 128),
    "long_500k": ("decode", 524_288, 1),
}


@dataclasses.dataclass
class InputSpec:
    """Abstract inputs for one dry-run cell."""
    kind: str                      # train | prefill | decode
    seq_len: int
    batch: int
    args: dict                     # name -> ShapeDtypeStruct pytree
    shardings: dict                # name -> PartitionSpec pytree (same struct)


def _batch_axes(multi_pod: bool):
    return ("pod", "data") if multi_pod else "data"


def lm_input_specs(cfg: ModelConfig, shape: str, multi_pod: bool = False,
                   skips: dict[str, str] | None = None) -> InputSpec | None:
    """Generic LM input specs; arch modules wrap this with their overrides."""
    if skips and shape in skips:
        return None
    kind, S, B = SHAPES[shape]
    ba = _batch_axes(multi_pod)
    i32, f_act = jnp.int32, cfg.dtype

    if kind == "train":
        args = {"batch": {
            "tokens": jax.ShapeDtypeStruct((B, S), i32),
            "labels": jax.ShapeDtypeStruct((B, S), i32),
            "mask": jax.ShapeDtypeStruct((B, S), jnp.float32),
        }}
        sh = {"batch": {
            "tokens": P(ba, None), "labels": P(ba, None), "mask": P(ba, None)}}
        return InputSpec(kind, S, B, args, sh)

    if kind == "prefill":
        args = {"batch": {"tokens": jax.ShapeDtypeStruct((B, S), i32)}}
        sh = {"batch": {"tokens": P(ba, None)}}
        return InputSpec(kind, S, B, args, sh)

    # decode: one new token against a cache of length S
    model = build_model(cfg)
    cache = jax.eval_shape(lambda: model.init_cache(B, S))
    from repro.sharding.rules import MULTI_POD_RULES, SINGLE_POD_RULES
    rules = MULTI_POD_RULES if multi_pod else SINGLE_POD_RULES
    if B == 1:  # long-context single-stream: batch cannot shard; replicate
        rules = dataclasses.replace(rules, rules={**rules.rules, "batch": None})
    cache_specs = model.cache_specs(rules)
    args = {"tokens": jax.ShapeDtypeStruct((B, 1), i32), "cache": cache}
    sh = {"tokens": P(rules.axis("batch"), None), "cache": cache_specs}
    return InputSpec(kind, S, B, args, sh)


def embeds_input_specs(cfg: ModelConfig, shape: str, multi_pod: bool = False,
                       skips: dict[str, str] | None = None,
                       num_image_tokens: int = 0) -> InputSpec | None:
    """Variant for modality-frontend-stub archs (audio frames / vision patches).

    For encoder (hubert): batch supplies precomputed frame embeddings.
    For VLM (llava): text tokens + patch embeddings; seq_len counts both.
    """
    if skips and shape in skips:
        return None
    kind, S, B = SHAPES[shape]
    ba = _batch_axes(multi_pod)
    f_act = cfg.dtype

    if num_image_tokens:  # VLM: tokens + image embeds
        base = lm_input_specs(cfg, shape, multi_pod, skips)
        if base is None or kind == "decode":
            return base
        S_text = S - num_image_tokens
        img = jax.ShapeDtypeStruct((B, num_image_tokens, cfg.d_model), f_act)
        base.args["batch"]["tokens"] = jax.ShapeDtypeStruct((B, S_text), jnp.int32)
        base.args["batch"]["image_embeds"] = img
        base.shardings["batch"]["image_embeds"] = P(ba, None, None)
        return base

    # encoder (audio): embeds in, masked-prediction labels for train
    if kind == "train":
        args = {"batch": {
            "embeds": jax.ShapeDtypeStruct((B, S, cfg.d_model), f_act),
            "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
            "mask": jax.ShapeDtypeStruct((B, S), jnp.float32),
        }}
        sh = {"batch": {"embeds": P(ba, None, None), "labels": P(ba, None),
                        "mask": P(ba, None)}}
        return InputSpec(kind, S, B, args, sh)
    if kind == "prefill":
        args = {"batch": {"embeds": jax.ShapeDtypeStruct((B, S, cfg.d_model), f_act)}}
        sh = {"batch": {"embeds": P(ba, None, None)}}
        return InputSpec(kind, S, B, args, sh)
    return None  # encoder-only: no decode cells


def smoke_batch(cfg: ModelConfig, key, batch: int = 2, seq: int = 16,
                num_image_tokens: int = 0, embeds: bool = False):
    """Concrete tiny batch for the per-arch smoke tests."""
    kt, kl, ke = jax.random.split(key, 3)
    if embeds:
        return {"embeds": jax.random.normal(ke, (batch, seq, cfg.d_model),
                                            cfg.dtype),
                "labels": jax.random.randint(kl, (batch, seq), 0, cfg.vocab),
                "mask": jnp.ones((batch, seq), jnp.float32)}
    b = {"tokens": jax.random.randint(kt, (batch, seq - num_image_tokens), 0,
                                      cfg.vocab),
         "labels": jax.random.randint(kl, (batch, seq), 0, cfg.vocab),
         "mask": jnp.ones((batch, seq), jnp.float32)}
    if num_image_tokens:
        b["image_embeds"] = jax.random.normal(
            ke, (batch, num_image_tokens, cfg.d_model), cfg.dtype)
    return b


__all__ = ["SHAPES", "InputSpec", "lm_input_specs", "embeds_input_specs",
           "smoke_batch"]
