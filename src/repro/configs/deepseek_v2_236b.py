"""deepseek-v2-236b [moe]: 60L d=5120 128H d_ff(expert)=1536 vocab=102400,
MoE 160 routed top-6 + 2 shared, MLA kv_lora=512 [arXiv:2405.04434; hf].

Per the assignment line, all 60 layers are MoE (the HF release keeps layer 0
dense; recorded as a deviation in DESIGN.md).  MLA dims follow the paper:
q_lora 1536, kv_lora 512, qk_nope 128, qk_rope 64, v_head 128.
Full attention -> long_500k skipped."""

from repro.models.transformer import ModelConfig
from repro.models.moe import MoEConfig
from .base import lm_input_specs

CONFIG = ModelConfig(
    name="deepseek-v2-236b", family="transformer",
    num_layers=60, d_model=5120, num_heads=128, num_kv_heads=128, head_dim=128,
    d_ff=1536, vocab=102400, act="silu",
    moe=MoEConfig(num_experts=160, top_k=6, d_ff_expert=1536, num_shared=2),
    mla={"q_lora": 1536, "kv_lora": 512, "rope_head_dim": 64, "v_head_dim": 128},
    rope_theta=10000.0, tie_embeddings=False,
)

SMOKE = ModelConfig(
    name="deepseek-smoke", family="transformer",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
    d_ff=64, vocab=256, act="silu",
    moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=32, num_shared=1),
    mla={"q_lora": 48, "kv_lora": 32, "rope_head_dim": 8, "v_head_dim": 16},
    tie_embeddings=False, q_block=8, kv_block=8, loss_chunk=8,
)

SKIPS = {"long_500k": "pure full attention (no sub-quadratic path)"}


def input_specs(shape: str, multi_pod: bool = False):
    return lm_input_specs(CONFIG, shape, multi_pod, SKIPS)
