"""moonshot-v1-16b-a3b [moe]: 48L d=2048 16H (kv=16) d_ff(expert)=1408
vocab=163840, MoE 64 routed top-6 [hf:moonshotai/Moonlight-16B-A3B; hf].
Assignment line specifies 64e top-6 (no shared experts listed; the HF release
adds 2 shared — recorded as a deviation in DESIGN.md).
Full attention -> long_500k skipped."""

from repro.models.transformer import ModelConfig
from repro.models.moe import MoEConfig
from .base import lm_input_specs

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b", family="transformer",
    num_layers=48, d_model=2048, num_heads=16, num_kv_heads=16, head_dim=128,
    d_ff=1408, vocab=163840, act="silu",
    moe=MoEConfig(num_experts=64, top_k=6, d_ff_expert=1408, num_shared=0),
    rope_theta=10000.0, tie_embeddings=False,
)

SMOKE = ModelConfig(
    name="moonshot-smoke", family="transformer",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
    d_ff=64, vocab=256, act="silu",
    moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=32, num_shared=0),
    tie_embeddings=False, q_block=8, kv_block=8, loss_chunk=8,
)

SKIPS = {"long_500k": "pure full attention (no sub-quadratic path)"}


def input_specs(shape: str, multi_pod: bool = False):
    return lm_input_specs(CONFIG, shape, multi_pod, SKIPS)
