"""The paper's own HMM workloads (Sec. VII-A parameter settings).

Defaults: |O|=50, edge probability p=0.253, K=512, T=512; forced-alignment
dataset analogue: left-to-right HMM with K=3965, T=256 (TIMIT via HTK in the
paper; synthesised here with the same structure/scale)."""

import dataclasses


@dataclasses.dataclass(frozen=True)
class HMMWorkload:
    name: str
    num_states: int
    seq_len: int
    num_obs: int = 50
    edge_prob: float = 0.253
    kind: str = "erdos_renyi"      # or "left_to_right"


DEFAULT = HMMWorkload("default", num_states=512, seq_len=512)
FORCED_ALIGNMENT = HMMWorkload("forced-alignment", num_states=3965,
                               seq_len=256, num_obs=256, kind="left_to_right")
SWEEP_K = [32, 64, 128, 256, 512, 1024, 2048]
SWEEP_T = [32, 64, 128, 256, 512, 1024, 2048]
SWEEP_P_EDGE = [0.05, 0.075, 0.113, 0.169, 0.253, 0.38, 0.57, 0.85, 1.0]
SWEEP_B = [32, 64, 128, 256, 512, 1024]
