"""tinyllama-1.1b [dense]: 22L d=2048 32H (GQA kv=4) d_ff=5632 vocab=32000.
Llama2-arch small [arXiv:2401.02385; hf]. Full attention -> long_500k skipped."""

from repro.models.transformer import ModelConfig
from .base import lm_input_specs

CONFIG = ModelConfig(
    name="tinyllama-1.1b", family="transformer",
    num_layers=22, d_model=2048, num_heads=32, num_kv_heads=4, head_dim=64,
    d_ff=5632, vocab=32000, act="silu", rope_theta=10000.0,
    tie_embeddings=False,
)

SMOKE = ModelConfig(
    name="tinyllama-smoke", family="transformer",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=160, vocab=256, act="silu", tie_embeddings=False,
    q_block=8, kv_block=8, loss_chunk=8,
)

SKIPS = {"long_500k": "pure full attention (no sub-quadratic path)"}


def input_specs(shape: str, multi_pod: bool = False):
    return lm_input_specs(CONFIG, shape, multi_pod, SKIPS)
