"""recurrentgemma-2b [hybrid]: 26L d=2560 10H (MQA kv=1, hd=256) d_ff=7680
vocab=256000 — RG-LRU + local attention at 1:2 ratio (rec, rec, attn)
[arXiv:2402.19427; hf].  Recurrent+local -> long_500k RUNS."""

from repro.models.transformer import ModelConfig
from .base import lm_input_specs

CONFIG = ModelConfig(
    name="recurrentgemma-2b", family="griffin",
    num_layers=26, d_model=2560, num_heads=10, num_kv_heads=1, head_dim=256,
    d_ff=7680, vocab=256000, act="gelu", window=2048, d_rnn=2560,
    rope_theta=10000.0, embed_scale=True, subquadratic=True,
)

SMOKE = ModelConfig(
    name="recurrentgemma-smoke", family="griffin",
    num_layers=5, d_model=64, num_heads=4, num_kv_heads=1, head_dim=16,
    d_ff=160, vocab=256, act="gelu", window=8, d_rnn=64, embed_scale=True,
    q_block=8, kv_block=8, loss_chunk=8, subquadratic=True,
)

SKIPS: dict = {}


def input_specs(shape: str, multi_pod: bool = False):
    return lm_input_specs(CONFIG, shape, multi_pod, SKIPS)
