"""hubert-xlarge [audio]: 48L d=1280 16H d_ff=5120 vocab=504 — encoder-only
(same arch as wav2vec2) [arXiv:2106.07447; unverified].

The modality frontend (CNN feature extractor) is a STUB per the assignment:
input_specs supplies precomputed frame embeddings (B, S, 1280).  Plain GELU MLP
(not gated), no rope (frontend handles position).  Head padded 504 -> 512 for
TP divisibility (8 dead classes, masked in the loss).
Encoder-only -> decode_32k and long_500k skipped (no autoregressive step).

This is the paper-primary arch: its emissions feed the FLASH-BS forced-
alignment head (serving/alignment.py), reproducing the paper's TIMIT workload.
"""

from repro.models.transformer import ModelConfig
from .base import embeds_input_specs

NUM_CLASSES = 504  # true classes; head padded to 512

CONFIG = ModelConfig(
    name="hubert-xlarge", family="transformer",
    num_layers=48, d_model=1280, num_heads=16, num_kv_heads=16, head_dim=80,
    d_ff=5120, vocab=512, act="gelu", encoder_only=True, embed_inputs=False,
    mlp_glu=False, use_rope=False, tie_embeddings=False,
)

SMOKE = ModelConfig(
    name="hubert-smoke", family="transformer",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
    d_ff=160, vocab=32, act="gelu", encoder_only=True, embed_inputs=False,
    mlp_glu=False, use_rope=False, tie_embeddings=False,
    q_block=8, kv_block=8, loss_chunk=8,
)

SKIPS = {
    "decode_32k": "encoder-only: no autoregressive decode step",
    "long_500k": "encoder-only: no autoregressive decode step",
}


def input_specs(shape: str, multi_pod: bool = False):
    return embeds_input_specs(CONFIG, shape, multi_pod, SKIPS)
