"""gemma-2b [dense]: 18L d=2048 8H (MQA kv=1) d_ff=16384 vocab=256000.
GeGLU, head_dim=256, embeddings scaled by sqrt(d) [arXiv:2403.08295; hf].
Full attention -> long_500k skipped."""

from repro.models.transformer import ModelConfig
from .base import lm_input_specs

CONFIG = ModelConfig(
    name="gemma-2b", family="transformer",
    num_layers=18, d_model=2048, num_heads=8, num_kv_heads=1, head_dim=256,
    d_ff=16384, vocab=256000, act="gelu", embed_scale=True,
    rope_theta=10000.0, tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="gemma-smoke", family="transformer",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=1, head_dim=32,
    d_ff=256, vocab=512, act="gelu", embed_scale=True, tie_embeddings=True,
    q_block=8, kv_block=8, loss_chunk=8,
)

SKIPS = {"long_500k": "pure full attention (no sub-quadratic path)"}


def input_specs(shape: str, multi_pod: bool = False):
    return lm_input_specs(CONFIG, shape, multi_pod, SKIPS)
