"""llava-next-34b [vlm]: 60L d=7168 56H (GQA kv=8) d_ff=20480 vocab=64000 —
anyres tiling [hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified].

The vision tower is a STUB per the assignment: input_specs supplies
precomputed patch embeddings (anyres: base 576 + 4 tiles x 576 = 2880 tokens)
prepended to the text sequence; seq_len counts image + text tokens.
Full attention -> long_500k skipped."""

from repro.models.transformer import ModelConfig
from .base import embeds_input_specs

NUM_IMAGE_TOKENS = 2880  # anyres: (1 base + 4 tiles) x 24x24 patches

CONFIG = ModelConfig(
    name="llava-next-34b", family="transformer",
    num_layers=60, d_model=7168, num_heads=56, num_kv_heads=8, head_dim=128,
    d_ff=20480, vocab=64000, act="silu", rope_theta=5000000.0,
    num_image_tokens=NUM_IMAGE_TOKENS, tie_embeddings=False,
)

SMOKE = ModelConfig(
    name="llava-smoke", family="transformer",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=160, vocab=256, act="silu", num_image_tokens=8, tie_embeddings=False,
    q_block=8, kv_block=8, loss_chunk=8,
)

SKIPS = {"long_500k": "pure full attention (no sub-quadratic path)"}


def input_specs(shape: str, multi_pod: bool = False):
    return embeds_input_specs(CONFIG, shape, multi_pod, SKIPS,
                              num_image_tokens=NUM_IMAGE_TOKENS)
