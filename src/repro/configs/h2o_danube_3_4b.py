"""h2o-danube-3-4b [dense]: 24L d=3840 32H (GQA kv=8) d_ff=10240 vocab=32000.
Llama+mistral mix with sliding-window attention [arXiv:2401.16818; unverified].
SWA (window 4096) is linear in context -> long_500k RUNS (window-sized cache)."""

from repro.models.transformer import ModelConfig
from .base import lm_input_specs

CONFIG = ModelConfig(
    name="h2o-danube-3-4b", family="transformer",
    num_layers=24, d_model=3840, num_heads=32, num_kv_heads=8, head_dim=120,
    d_ff=10240, vocab=32000, act="silu", window=4096, rope_theta=10000.0,
    tie_embeddings=False, subquadratic=True,
)

SMOKE = ModelConfig(
    name="danube-smoke", family="transformer",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=160, vocab=256, act="silu", window=8, tie_embeddings=False,
    q_block=8, kv_block=8, loss_chunk=8, subquadratic=True,
)

SKIPS: dict = {}


def input_specs(shape: str, multi_pod: bool = False):
    return lm_input_specs(CONFIG, shape, multi_pod, SKIPS)
