"""xlstm-350m [ssm]: 24L d=1024 4H vocab=50304, alternating sLSTM + mLSTM
blocks (d_ff=0: projections live inside the blocks) [arXiv:2405.04517;
unverified].  Recurrent -> long_500k RUNS."""

from repro.models.transformer import ModelConfig
from .base import lm_input_specs

CONFIG = ModelConfig(
    name="xlstm-350m", family="xlstm",
    num_layers=24, d_model=1024, num_heads=4, num_kv_heads=4,
    d_ff=0, vocab=50304, subquadratic=True,
)

SMOKE = ModelConfig(
    name="xlstm-smoke", family="xlstm",
    num_layers=4, d_model=64, num_heads=4, num_kv_heads=4, d_ff=0, vocab=256,
    q_block=8, kv_block=8, loss_chunk=8, subquadratic=True,
)

SKIPS: dict = {}


def input_specs(shape: str, multi_pod: bool = False):
    return lm_input_specs(CONFIG, shape, multi_pod, SKIPS)
