"""Assigned architecture configs (one module per arch) + paper HMM workloads.

ARCHS maps the assignment id to its config module; each module exposes
CONFIG, SMOKE, SKIPS and input_specs(shape, multi_pod).
"""

import importlib

ARCH_IDS = [
    "recurrentgemma_2b",
    "deepseek_v2_236b",
    "moonshot_v1_16b_a3b",
    "tinyllama_1_1b",
    "h2o_danube_3_4b",
    "granite_8b",
    "gemma_2b",
    "xlstm_350m",
    "hubert_xlarge",
    "llava_next_34b",
]


def get_arch(arch_id: str):
    """Return the config module for an assignment id (dashes tolerated)."""
    mod = arch_id.replace("-", "_").replace(".", "_")
    if mod not in ARCH_IDS:
        raise ValueError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    return importlib.import_module(f"repro.configs.{mod}")


__all__ = ["ARCH_IDS", "get_arch"]
