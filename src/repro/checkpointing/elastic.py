"""Elastic rescaling: move a training state between mesh shapes.

A checkpoint written on one mesh restores onto any other mesh (the manager
stores unsharded host arrays; `reshard` device_puts them under the new
topology's specs).  `plan_rescale` validates that the new mesh still divides
every sharded axis — the guard a 1000-node scheduler calls before committing
a shrink/grow."""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding

from repro.runtime.jaxcompat import abstract_mesh


def abstract_target_mesh(axis_sizes, axis_names):
    """Describe a rescale *target* topology without owning its devices.

    `plan_rescale` only reads ``mesh.shape``, so a scheduler planning a
    shrink/grow on a login host passes the result of this instead of a real
    `Mesh`.  Goes through `runtime.jaxcompat` because `AbstractMesh`'s
    constructor signature differs between jax 0.4.x and current jax.
    """
    return abstract_mesh(axis_sizes, axis_names)


def reshard(tree, mesh: Mesh, spec_tree):
    """device_put every leaf under (mesh, spec)."""
    return jax.tree_util.tree_map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)),
        tree, spec_tree, is_leaf=lambda x: not isinstance(x, (dict, list, tuple)))


def plan_rescale(shape_tree, spec_tree, mesh: Mesh) -> list[str]:
    """Return a list of violations (empty = the rescale is legal)."""
    problems: list[str] = []

    def visit(path, shape, spec):
        dims = tuple(spec) if spec is not None else ()
        for i, ax in enumerate(dims):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            total = int(np.prod([mesh.shape[a] for a in axes]))
            if i >= len(shape) or shape[i] % total:
                problems.append(
                    f"{path}: dim {i} of {shape} not divisible by {ax}={total}")

    def walk(path, shapes, specs):
        if isinstance(shapes, dict):
            for k in shapes:
                walk(f"{path}/{k}", shapes[k], specs[k])
        elif isinstance(shapes, (list, tuple)):
            for i, (sh, sp) in enumerate(zip(shapes, specs)):
                walk(f"{path}[{i}]", sh, sp)
        else:
            visit(path, shapes.shape if hasattr(shapes, "shape") else shapes,
                  specs)

    walk("", shapes=shape_tree, specs=spec_tree)
    return problems


__all__ = ["reshard", "plan_rescale", "abstract_target_mesh"]
