"""Checkpointing: async, atomic, keep-N, mesh-portable.

Layout per step:  <dir>/step_<N>.tmp/  -> fsync'd -> rename to step_<N>/
    leaves.npz      every pytree leaf, key = flattened path
    meta.json       step, pytree structure digest, mesh shape, timestamp

* Writes happen on a background thread from host copies (training never
  blocks on disk I/O beyond the device->host fetch).
* Restore is mesh-agnostic: leaves load on host and are device_put with the
  *target* sharding — this is also the elastic-rescale path (same checkpoint,
  new mesh), see elastic.py.
* Atomic rename means a crash mid-write can never corrupt the latest
  checkpoint; `latest_step` only ever sees fully-renamed directories.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time

import jax
import numpy as np


def _savable(arr: np.ndarray) -> np.ndarray:
    """npz supports only builtin dtypes: upcast bf16/f8 etc. to f32
    (lossless for bf16; restore() casts back to the target leaf dtype)."""
    if arr.dtype.kind == "V" or arr.dtype.name in ("bfloat16", "float8_e4m3fn",
                                                   "float8_e5m2", "float16"):
        return arr.astype(np.float32)
    return arr


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = _savable(np.asarray(leaf))
    return out, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # -- save --------------------------------------------------------------
    def save(self, step: int, state, blocking: bool = False):
        """Snapshot `state` at `step`. Returns immediately unless blocking."""
        host, _ = _flatten(jax.device_get(state))
        self.wait()  # at most one outstanding write

        def write():
            tmp = os.path.join(self.dir, f"step_{step}.tmp")
            final = os.path.join(self.dir, f"step_{step}")
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, "leaves.npz"), **host)
            meta = {"step": step, "time": time.time(),
                    "num_leaves": len(host)}
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump(meta, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._gc()

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)

    # -- restore -------------------------------------------------------------
    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name.split("_")[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like, shardings=None):
        """Load step into the structure of `like` (shapes/dtypes validated).

        shardings: optional matching pytree of jax.sharding.Sharding — the
        elastic-rescale path: same bytes, new mesh.
        """
        self.wait()
        path = os.path.join(self.dir, f"step_{step}", "leaves.npz")
        data = np.load(path)
        # reference shapes/dtypes come from the RAW leaves of `like` (NOT the
        # _savable view — that upcasts bf16 to f32 for npz and restoring at
        # f32 would silently change model numerics)
        flat, treedef = jax.tree_util.tree_flatten_with_path(like)
        restored = {}
        order = []
        for p, leaf in flat:
            key = "/".join(str(getattr(q, "key", getattr(q, "idx", q)))
                           for q in p)
            arr = data[key]
            ref_shape = getattr(leaf, "shape", ())
            ref_dtype = getattr(leaf, "dtype", arr.dtype)
            if arr.shape != tuple(ref_shape):
                raise ValueError(f"{key}: checkpoint {arr.shape} != expected "
                                 f"{ref_shape}")
            restored[key] = arr.astype(ref_dtype)
            order.append(key)
        leaves = [restored[k] for k in order]
        tree = jax.tree_util.tree_unflatten(treedef, leaves)
        if shardings is not None:
            tree = jax.tree_util.tree_map(
                lambda x, s: jax.device_put(x, s), tree, shardings)
        return tree


__all__ = ["CheckpointManager"]
