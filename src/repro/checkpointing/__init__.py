"""repro.checkpointing"""
