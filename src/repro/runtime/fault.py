"""Fault tolerance runtime: heartbeats, straggler detection, supervised steps.

On a real multi-host deployment these hooks wrap `jax.distributed` liveness;
here the same state machine is driven by injectable clocks/chaos hooks so the
policies (restart-from-checkpoint, straggler skip, elastic shrink) are unit-
testable on one host — the part of fault tolerance that is actually logic, not
plumbing.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable


@dataclasses.dataclass
class HeartbeatMonitor:
    """Tracks per-worker liveness; a worker missing `timeout_s` is dead."""
    num_workers: int
    timeout_s: float = 60.0
    clock: Callable[[], float] = time.monotonic

    def __post_init__(self):
        now = self.clock()
        self.last_seen = {w: now for w in range(self.num_workers)}

    def beat(self, worker: int):
        self.last_seen[worker] = self.clock()

    def dead_workers(self) -> list[int]:
        now = self.clock()
        return [w for w, t in self.last_seen.items()
                if now - t > self.timeout_s]

    def healthy(self) -> bool:
        return not self.dead_workers()


@dataclasses.dataclass
class StragglerDetector:
    """Flags workers whose step time exceeds `factor` x the rolling median."""
    num_workers: int
    factor: float = 3.0
    window: int = 16

    def __post_init__(self):
        self.history: list[float] = []
        self.last: dict[int, float] = {}

    def record(self, worker: int, step_time: float):
        self.last[worker] = step_time
        self.history.append(step_time)
        self.history = self.history[-self.window * self.num_workers:]

    def median(self) -> float:
        h = sorted(self.history)
        if not h:
            return 0.0
        n = len(h)
        if n % 2:
            return h[n // 2]
        return 0.5 * (h[n // 2 - 1] + h[n // 2])

    def stragglers(self) -> list[int]:
        med = self.median()
        if med <= 0:
            return []
        return [w for w, t in self.last.items() if t > self.factor * med]


class SupervisedLoop:
    """Drives train steps under failure policy:

       * checkpoint every `ckpt_every` steps (async);
       * on a step exception (preemption / injected chaos): restore the latest
         checkpoint and continue — the data pipeline is step-indexed so the
         replayed batches are identical;
       * on persistent failure of the same step `max_retries` times: raise.
    """

    def __init__(self, step_fn, state, ckpt_manager, batch_fn,
                 ckpt_every: int = 50, max_retries: int = 3,
                 chaos: Callable[[int], None] | None = None):
        self.step_fn = step_fn
        self.state = state
        self.ckpt = ckpt_manager
        self.batch_fn = batch_fn
        self.ckpt_every = ckpt_every
        self.max_retries = max_retries
        self.chaos = chaos
        self.restarts = 0

    def run(self, start_step: int, num_steps: int, like=None):
        step = start_step
        metrics_log = []
        retries = 0
        while step < start_step + num_steps:
            try:
                if self.chaos is not None:
                    self.chaos(step)  # may raise to simulate a node loss
                batch = self.batch_fn(step)
                self.state, metrics = self.step_fn(self.state, batch)
                metrics_log.append({k: float(v) for k, v in metrics.items()})
                if (step + 1) % self.ckpt_every == 0:
                    self.ckpt.save(step + 1, self.state)
                step += 1
                retries = 0
            except RuntimeError:
                retries += 1
                self.restarts += 1
                if retries > self.max_retries:
                    raise
                self.ckpt.wait()  # barrier on in-flight async writes first
                latest = self.ckpt.latest_step()
                if latest is not None:
                    self.state = self.ckpt.restore(latest, like or self.state)
                    step = latest
        self.ckpt.save(step, self.state, blocking=True)
        return self.state, metrics_log


__all__ = ["HeartbeatMonitor", "StragglerDetector", "SupervisedLoop"]
