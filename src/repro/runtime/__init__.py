"""repro.runtime — fault tolerance (`fault`, stdlib-only) + jax
version-compat shims (`jaxcompat`, imported explicitly so pure-Python
supervisor processes never pay the jax import)."""
