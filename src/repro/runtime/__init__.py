"""repro.runtime"""
