"""Version-portable shims over the jax APIs that drift across releases.

This repo runs on the pinned internal toolchain (jax 0.4.37) *and* on current
jax.  Three API surfaces the distributed layer depends on moved between those
versions, and every call site used to hardcode one side of the move — which is
how the whole subsystem went dark on 0.4.x.  This module bridges all three:

  * ``shard_map`` — lives at ``jax.shard_map`` on new jax but only under
    ``jax.experimental.shard_map`` on 0.4.x, and the replication-check kwarg
    was renamed ``check_rep`` -> ``check_vma``.
  * ``make_mesh`` — ``jax.make_mesh`` grew an ``axis_types`` kwarg, and
    ``jax.sharding.AxisType`` itself only exists on newer jax.
  * ``abstract_mesh`` — ``jax.sharding.AbstractMesh`` changed its constructor
    from a ``((name, size), ...)`` shape tuple (0.4.x) to positional
    ``(axis_sizes, axis_names)`` (current).

Feature probes run exactly once, at import time; call sites branch on the
resulting module-level booleans instead of sniffing jax versions.  Importing
this module never touches jax device state (the dry-runs set ``XLA_FLAGS``
before the first device query, and must keep working).
"""

from __future__ import annotations

import inspect
import re

import jax
from jax.sharding import AbstractMesh, Mesh

# ---------------------------------------------------------------------------
# Feature probes (once, at import)
# ---------------------------------------------------------------------------

if hasattr(jax, "shard_map"):
    _shard_map_impl = jax.shard_map
else:  # jax <= 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map_impl

#: shard_map takes ``check_vma`` (new) rather than ``check_rep`` (0.4.x).
HAS_CHECK_VMA = "check_vma" in inspect.signature(_shard_map_impl).parameters

#: jax.sharding.AxisType exists (explicit-sharding-aware meshes).
HAS_AXIS_TYPE = hasattr(jax.sharding, "AxisType")

#: jax.make_mesh accepts ``axis_types``.
HAS_MAKE_MESH_AXIS_TYPES = hasattr(jax, "make_mesh") and (
    "axis_types" in inspect.signature(jax.make_mesh).parameters)

#: AbstractMesh uses the old ``shape_tuple`` of (name, size) pairs (0.4.x).
ABSTRACT_MESH_TAKES_PAIRS = (
    "shape_tuple" in inspect.signature(AbstractMesh.__init__).parameters)


def jax_version() -> tuple[int, ...]:
    """The installed jax version as an int tuple, e.g. ``(0, 4, 37)``.

    Tolerates pre-release / dev suffixes ("0.5.0rc1", "0.4.38.dev20240101"):
    each dot segment contributes its leading digits.
    """
    parts = []
    for p in jax.__version__.split(".")[:3]:
        m = re.match(r"\d+", p)
        parts.append(int(m.group()) if m else 0)
    return tuple(parts)


# ---------------------------------------------------------------------------
# Portable constructors / wrappers
# ---------------------------------------------------------------------------

def shard_map(f, *, mesh, in_specs, out_specs, check_replication: bool = False):
    """`shard_map` that runs on 0.4.x and current jax.

    ``check_replication`` maps onto ``check_vma`` (new) or ``check_rep``
    (0.4.x); both default False here because the distributed decoders combine
    shards with explicit collectives (pmax / all_gather) whose replication
    the static checker cannot always prove.
    """
    kwarg = "check_vma" if HAS_CHECK_VMA else "check_rep"
    return _shard_map_impl(f, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs,
                           **{kwarg: check_replication})


def make_mesh(axis_shapes, axis_names, *, devices=None) -> Mesh:
    """`jax.make_mesh` passing ``AxisType.Auto`` only where supported."""
    if HAS_MAKE_MESH_AXIS_TYPES and HAS_AXIS_TYPE:
        axis_types = (jax.sharding.AxisType.Auto,) * len(axis_names)
        return jax.make_mesh(axis_shapes, axis_names, devices=devices,
                             axis_types=axis_types)
    if hasattr(jax, "make_mesh"):
        return jax.make_mesh(axis_shapes, axis_names, devices=devices)
    # pre-make_mesh jax: reshape the flat device list by hand
    import numpy as np
    devs = list(jax.devices()) if devices is None else list(devices)
    n = int(np.prod(axis_shapes))
    return Mesh(np.asarray(devs[:n]).reshape(axis_shapes), axis_names)


def abstract_mesh(axis_sizes, axis_names) -> AbstractMesh:
    """Device-free `AbstractMesh` under either constructor signature.

    Use this to describe a *target* topology (e.g. for elastic-rescale
    planning) on hosts that do not have the devices — only axis names and
    sizes are recorded.
    """
    axis_sizes = tuple(int(s) for s in axis_sizes)
    axis_names = tuple(axis_names)
    if len(axis_sizes) != len(axis_names):
        raise ValueError(
            f"axis_sizes {axis_sizes} and axis_names {axis_names} disagree")
    if ABSTRACT_MESH_TAKES_PAIRS:
        return AbstractMesh(tuple(zip(axis_names, axis_sizes)))
    return AbstractMesh(axis_sizes, axis_names)


__all__ = [
    "shard_map", "make_mesh", "abstract_mesh", "jax_version",
    "HAS_CHECK_VMA", "HAS_AXIS_TYPE", "HAS_MAKE_MESH_AXIS_TYPES",
    "ABSTRACT_MESH_TAKES_PAIRS",
]
