"""Streaming decode sessions: the serving face of the online Viterbi subsystem.

``StreamSession`` wraps one live decode — frames go in, committed path
prefixes come out as soon as they are final — and ``StreamMux`` multiplexes
many concurrent sessions the way ``BatchScheduler`` multiplexes offline
requests: sessions are grouped by their *block size* (the bucket), frames are
buffered per session, and the DP only ever advances in whole blocks, so the
jitted chunk kernel sees one shape per bucket instead of one per ragged
arrival.  Leftover frames shorter than a block run once, at ``finish()``.

    mux = StreamMux(hmm.log_pi, hmm.log_A, cfg=StreamConfig(max_lag=64))
    sid = mux.open(block=128)
    out = mux.feed(sid, frames)          # {"committed": (n,) int32, ...}
    path, score = mux.finish(sid)
"""

from __future__ import annotations

import dataclasses
import itertools
import time

import numpy as np

from repro.core import as_decode_spec
from repro.core.spec import OnlineBeamSpec, OnlineSpec


@dataclasses.dataclass(frozen=True)
class StreamConfig:
    """Per-deployment resource profile for streaming decode.

    method "online" is exact (O(W*K) live state, W the convergence window);
    "online_beam" caps live state at O(W*B) independent of K.  ``max_lag``
    bounds commit latency (and W) at the cost of exactness on forced steps.

    Legacy string form; sessions also accept an `OnlineSpec` /
    `OnlineBeamSpec` directly (`to_spec()` is the conversion).
    """
    method: str = "online"            # online | online_beam
    beam_width: int = 128
    kchunk: int = 128                 # K-chunking of the beam transition
    max_lag: int | None = None

    def to_spec(self):
        if self.method == "online":
            return OnlineSpec(max_lag=self.max_lag)
        if self.method == "online_beam":
            return OnlineBeamSpec(beam_width=self.beam_width,
                                  kchunk=self.kchunk, max_lag=self.max_lag)
        raise ValueError(f"unknown stream method {self.method!r}")


def _make_decoder(log_pi, log_A, cfg):
    spec = as_decode_spec(cfg)
    if not isinstance(spec, (OnlineSpec, OnlineBeamSpec)):
        raise ValueError(f"streaming needs OnlineSpec/OnlineBeamSpec, "
                         f"got {type(spec).__name__}")
    return spec.make_streaming(log_pi, log_A)


class StreamSession:
    """One live decode: ``feed(chunk) -> committed_prefix``.

    Frames are buffered and the DP advances in fixed ``block``-sized chunks
    (one jit shape per block size); anything still buffered is drained by
    ``finish()``.
    """

    def __init__(self, log_pi, log_A, cfg: StreamConfig = StreamConfig(),
                 *, block: int = 128, sid: int = 0):
        self.sid = sid
        self.block = int(block)
        self.cfg = cfg
        self.decoder = _make_decoder(log_pi, log_A, cfg)
        self._buf: list[np.ndarray] = []
        self._buffered = 0
        self._final: tuple[np.ndarray, float] | None = None
        self.opened = time.monotonic()
        self.first_commit_s: float | None = None
        self.frames_in = 0

    def feed(self, frames) -> np.ndarray:
        """Buffer (C, K) frames; run whole blocks; return newly-final states."""
        if self._final is not None:
            raise RuntimeError(
                f"session {self.sid} already finished; open a new one")
        frames = np.asarray(frames, dtype=np.float32)
        if frames.ndim != 2:
            raise ValueError(f"expected (C, K) frames, got {frames.shape}")
        self.frames_in += frames.shape[0]
        self._buf.append(frames)
        self._buffered += frames.shape[0]
        out: list[np.ndarray] = []
        if self._buffered >= self.block:
            pending = np.concatenate(self._buf, axis=0)
            n_blocks = pending.shape[0] // self.block
            for i in range(n_blocks):
                out.append(self.decoder.feed(
                    pending[i * self.block:(i + 1) * self.block]))
            rest = pending[n_blocks * self.block:]
            self._buf = [rest] if rest.shape[0] else []
            self._buffered = rest.shape[0]
        committed = (np.concatenate(out) if out
                     else np.zeros((0,), np.int32))
        if committed.shape[0] and self.first_commit_s is None:
            self.first_commit_s = time.monotonic() - self.opened
        return committed

    def finish(self) -> tuple[np.ndarray, float]:
        """Drain the buffer, flush the decoder; returns (full path, score).

        Idempotent: a second ``finish()`` returns the same result instead of
        re-flushing a dead decoder.
        """
        if self._final is None:
            if self._buffered:
                self.decoder.feed(np.concatenate(self._buf, axis=0))
                self._buf, self._buffered = [], 0
            self.decoder.flush()
            self._final = (self.decoder.path, self.decoder.score)
        return self._final

    @property
    def lag(self) -> int:
        return self.decoder.lag + self._buffered

    def live_state_bytes(self) -> int:
        """Live bytes held for this session: decoder window + feed buffer.

        The buffered frames are as live as the DP window — leaving them out
        under-reports pressure (and made the metric sit flat while sub-block
        feeds accumulated), which is exactly what an admission controller
        must not see.
        """
        return (self.decoder.live_state_bytes()
                + self._buffered * self.decoder.K * 4)


class StreamMux:
    """Many concurrent ``StreamSession``s over one shared model.

    The ``BatchScheduler`` idea applied to streams: sessions are bucketed by
    block size so every session in a bucket drives the *same* compiled chunk
    step, and per-bucket round-robin keeps the jit cache and the device warm
    under mixed traffic.  (State stays per-session — streaming DP carries are
    stateful — so the win is shape bucketing, not cross-session batching.)

    Bucketing has head-of-line blocking baked in: a session joining
    mid-flight buffers until its bucket's block fills.  Pass ``inflight=``
    (an `serving.inflight.InflightScheduler`) and exact/lagged ``"online"``
    sessions are routed straight into the continuous-batching tier instead —
    served within one *block* of arrival, one batched kernel call per step
    regardless of how many sessions are live.  ``"online_beam"`` sessions
    (and everything when no scheduler is configured) keep the bucketing
    path, so the old behavior is the fallback, not a casualty.
    """

    def __init__(self, log_pi, log_A, cfg: StreamConfig = StreamConfig(),
                 blocks: tuple[int, ...] = (32, 128, 512),
                 inflight=None):
        self.log_pi = log_pi
        self.log_A = log_A
        self.cfg = cfg
        self.blocks = tuple(sorted(blocks))
        self.inflight = inflight
        self._routed: dict[int, int] = {}   # mux sid -> inflight sid
        self._sessions: dict[int, StreamSession] = {}
        self._ids = itertools.count()
        self.stats = {"opened": 0, "finished": 0, "frames": 0, "commits": 0,
                      "routed_inflight": 0}

    def _bucket(self, block: int) -> int:
        for b in self.blocks:
            if block <= b:
                return b
        return self.blocks[-1]

    def _route_inflight(self) -> bool:
        return (self.inflight is not None and self.cfg.method == "online")

    def open(self, block: int = 128) -> int:
        sid = next(self._ids)
        if self._route_inflight():
            self._routed[sid] = self.inflight.submit(max_lag=self.cfg.max_lag)
            self.stats["opened"] += 1
            self.stats["routed_inflight"] += 1
            return sid
        self._sessions[sid] = StreamSession(
            self.log_pi, self.log_A, self.cfg,
            block=self._bucket(block), sid=sid)
        self.stats["opened"] += 1
        return sid

    def _session(self, sid: int) -> StreamSession:
        try:
            return self._sessions[sid]
        except KeyError:
            raise KeyError(f"unknown or already-finished session {sid}"
                           ) from None

    def feed(self, sid: int, frames) -> dict:
        if sid in self._routed:
            isid = self._routed[sid]
            self.inflight.feed(isid, frames)
            self.inflight.pump()
            committed = self.inflight.collect(isid)
            self.stats["frames"] += int(np.asarray(frames).shape[0])
            self.stats["commits"] += int(committed.shape[0])
            return {"committed": committed, "lag": self.inflight.lag(isid),
                    "n_committed": self.inflight.n_committed(isid)}
        sess = self._session(sid)
        committed = sess.feed(frames)
        self.stats["frames"] += int(np.asarray(frames).shape[0])
        self.stats["commits"] += int(committed.shape[0])
        return {"committed": committed, "lag": sess.lag,
                "n_committed": sess.decoder.n_committed}

    def finish(self, sid: int) -> tuple[np.ndarray, float]:
        if sid in self._routed:
            isid = self._routed.pop(sid)
            self.stats["finished"] += 1
            return self.inflight.finish(isid)
        sess = self._session(sid)
        del self._sessions[sid]
        self.stats["finished"] += 1
        return sess.finish()

    def sessions_by_bucket(self) -> dict[int, list[int]]:
        out: dict[int, list[int]] = {b: [] for b in self.blocks}
        for sid, s in self._sessions.items():
            out[s.block].append(sid)
        return out

    def live_state_bytes(self) -> int:
        total = sum(s.live_state_bytes() for s in self._sessions.values())
        if self.inflight is not None:
            total += self.inflight.live_state_bytes()
        return total


__all__ = ["StreamConfig", "StreamSession", "StreamMux"]
