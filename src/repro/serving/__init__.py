"""repro.serving"""
