"""repro.serving — batch (scheduler/alignment) and streaming (stream) decode."""

from .scheduler import Request, BatchScheduler
from .stream import StreamConfig, StreamSession, StreamMux

__all__ = ["Request", "BatchScheduler",
           "StreamConfig", "StreamSession", "StreamMux"]
