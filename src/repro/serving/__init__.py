"""repro.serving — batch (scheduler/alignment), streaming (stream), and
continuous inflight batching (inflight) decode tiers."""

from .scheduler import Request, BatchScheduler
from .stream import StreamConfig, StreamSession, StreamMux
from .inflight import InflightScheduler, AdmissionRejected

__all__ = ["Request", "BatchScheduler",
           "StreamConfig", "StreamSession", "StreamMux",
           "InflightScheduler", "AdmissionRejected"]
