"""Forced-alignment serving head: encoder emissions -> FLASH-BS Viterbi paths.

This is the paper's workload running as a production operator: hubert-xlarge
produces per-frame class posteriors (B, T, 504); a left-to-right HMM over the
target transcription's states constrains the decode; FLASH-BS (dynamic beam)
returns the per-frame alignment.

The head is a thin wrapper around `core.ViterbiDecoder`: the alignment config
resolves to a typed `DecodeSpec` (any batchable spec works — hand one in
directly, or let `core.planner.plan` pick it from a memory budget), the
decoder object owns jit caching, ragged `lengths`, and mesh sharding.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import (ViterbiDecoder, as_decode_spec, spec_from_tunables,
                        LexiconConstraint, with_constraint)
from repro.core.hmm import HMM


@dataclasses.dataclass(frozen=True)
class AlignmentConfig:
    """Legacy string-form alignment profile; `to_spec()` is the typed view.

    The batched serving path historically ran with whole-layer vectorisation
    (`lanes=None`), so that is what the conversion pins.
    """
    method: str = "flash_bs"       # flash | flash_bs | vanilla | fused
    beam_width: int = 128
    parallelism: int = 8
    chunk: int = 128

    def to_spec(self):
        # spec_from_tunables drops the fields `method` does not consume —
        # the legacy container always carried all four, so no warning here.
        spec, _ = spec_from_tunables(self.method, dict(
            beam_width=self.beam_width, parallelism=self.parallelism,
            chunk=self.chunk, lanes=None))
        return spec


def make_alignment_head(hmm_log_pi, hmm_log_A, cfg, *,
                        mesh=None, data_axis: str = "data"):
    """Returns align(emissions (B, T, K), lengths=None) -> (paths, scores).

    `cfg` is a `DecodeSpec` (preferred) or a legacy `AlignmentConfig`.

    `lengths` (B,) gives each request's true frame count; pad frames run as
    tropical-identity steps inside `viterbi_decode_batch`, so results are
    bit-identical to unbatched decodes of the unpadded payloads (for exact
    methods; FLASH-BS keeps its beam approximation but no pad corruption).
    This is the `decode_batch_fn` contract `BatchScheduler` expects.

    With ``mesh=`` the request bucket shards over ``data_axis`` via
    `ViterbiDecoder.decode_sharded`, which pads non-divisible bucket sizes
    with length-1 dummy rows and slices back — per-request results are
    unaffected (vmap lanes never interact).
    """
    spec = as_decode_spec(cfg)
    dec = ViterbiDecoder(spec, hmm_log_pi, hmm_log_A)

    def align(em, lengths=None):
        if mesh is not None:
            return dec.decode_sharded(em, lengths, mesh=mesh,
                                      data_axis=data_axis)
        return dec.decode_batch(em, lengths)

    align.decoder = dec
    return align


def make_lexicon_align_head(hmm_log_pi, hmm_log_A, words, *, cfg=None,
                            self_loops: bool = True, loop_words: bool = True,
                            mesh=None, data_axis: str = "data"):
    """Lexicon-constrained forced alignment: only lexicon arcs survive.

    `words` is the `LexiconConstraint` vocabulary — a sequence of words, each
    a sequence of pronunciation alternatives, each a state sequence (e.g.
    ``[((0, 1, 2), (0, 3, 2)), ((4, 5),)]``).  The constraint compiles the
    trie's arc set into additive {0, NEG_INF} penalties that every decode
    path fuses into its DP adds, so results are bit-identical to decoding
    the `constrain_inputs`-masked HMM densely — but the planner can also
    price the shrunken live-state set (`constraint.live_states`).

    `cfg` is a `DecodeSpec` or legacy `AlignmentConfig` (default: the
    standard FLASH-BS serving profile); its `constraint` field is replaced.
    Returns the same ``align(emissions, lengths=None)`` callable as
    `make_alignment_head`, with ``align.decoder`` / ``align.constraint``
    attached for introspection.
    """
    constraint = LexiconConstraint(words, self_loops=self_loops,
                                   loop_words=loop_words)
    spec = as_decode_spec(AlignmentConfig() if cfg is None else cfg)
    spec = with_constraint(spec, constraint)
    align = make_alignment_head(hmm_log_pi, hmm_log_A, spec,
                                mesh=mesh, data_axis=data_axis)
    align.constraint = constraint
    return align


def make_e2e_align_step(model, params_treedef_hint, hmm: HMM,
                        cfg, num_classes: int):
    """Encoder forward + log-softmax emissions + Viterbi alignment, one jit.

    The serving step for the hubert cells: batch {"embeds": (B, S, D)} ->
    (paths (B, S), scores (B,)).  `cfg` is a `DecodeSpec` or legacy
    `AlignmentConfig`.
    """
    spec = as_decode_spec(cfg)
    if not spec.jittable:
        raise ValueError(f"{type(spec).__name__} cannot run inside the "
                         f"jitted e2e step; use an offline (jittable) spec")

    def step(params, batch):
        x = batch["embeds"]
        # encoder forward reusing the model's loss-path stack
        from repro.models.transformer import _run_stack
        from repro.models.common import rms_norm
        h, _, _ = _run_stack(model.cfg, params, x.astype(model.cfg.dtype),
                             jnp.arange(x.shape[1]), collect_kv=False)
        h = rms_norm(h, params["ln_out"])
        logits = (h @ params["head"]).astype(jnp.float32)
        em = jax.nn.log_softmax(logits[..., :num_classes], axis=-1)
        return jax.vmap(lambda e: spec.run(hmm.log_pi, hmm.log_A, e))(em)

    return step


__all__ = ["AlignmentConfig", "make_alignment_head",
           "make_lexicon_align_head", "make_e2e_align_step"]
