"""Forced-alignment serving head: encoder emissions -> FLASH-BS Viterbi paths.

This is the paper's workload running as a production operator: hubert-xlarge
produces per-frame class posteriors (B, T, 504); a left-to-right HMM over the
target transcription's states constrains the decode; FLASH-BS (dynamic beam)
returns the per-frame alignment.  Batch shards over the data axis; the decode
per sequence runs the full FLASH wavefront (lanes=None vectorised).

`method`/`beam_width`/`parallelism` plumb the paper's adaptivity: the same
serving binary turns resource knobs instead of swapping decoders.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core import flash_bs_viterbi, viterbi_decode_batch
from repro.core.hmm import HMM


@dataclasses.dataclass(frozen=True)
class AlignmentConfig:
    method: str = "flash_bs"       # flash | flash_bs | vanilla | fused
    beam_width: int = 128
    parallelism: int = 8
    chunk: int = 128


def make_alignment_head(hmm_log_pi, hmm_log_A, cfg: AlignmentConfig, *,
                        mesh=None, data_axis: str = "data"):
    """Returns align(emissions (B, T, K), lengths=None) -> (paths, scores).

    `lengths` (B,) gives each request's true frame count; pad frames run as
    tropical-identity steps inside `viterbi_decode_batch`, so results are
    bit-identical to unbatched decodes of the unpadded payloads (for exact
    methods; FLASH-BS keeps its beam approximation but no pad corruption).
    This is the `decode_batch_fn` contract `BatchScheduler` expects.

    With ``mesh=`` the request bucket shards over ``data_axis``
    (`viterbi_decode_batch`'s multi-device path).  Buckets whose size does
    not divide the axis are padded up with length-1 dummy rows and sliced
    back — per-request results are unaffected (vmap lanes never interact).
    """

    @jax.jit
    def _align(em, lengths):
        return viterbi_decode_batch(em, hmm_log_pi, hmm_log_A, lengths,
                                    method=cfg.method,
                                    parallelism=cfg.parallelism, lanes=None,
                                    beam_width=cfg.beam_width, chunk=cfg.chunk,
                                    mesh=mesh, data_axis=data_axis)

    def align(em, lengths=None):
        em = jnp.asarray(em)
        B = em.shape[0]
        if lengths is None:
            lengths = jnp.full((B,), em.shape[1], jnp.int32)
        lengths = jnp.asarray(lengths, jnp.int32)
        if mesh is not None:
            pad_b = -B % mesh.shape[data_axis]
            if pad_b:
                em = jnp.concatenate(
                    [em, jnp.zeros((pad_b,) + em.shape[1:], em.dtype)])
                lengths = jnp.concatenate(
                    [lengths, jnp.ones((pad_b,), jnp.int32)])
        paths, scores = _align(em, lengths)
        return paths[:B], scores[:B]

    return align


def make_e2e_align_step(model, params_treedef_hint, hmm: HMM,
                        cfg: AlignmentConfig, num_classes: int):
    """Encoder forward + log-softmax emissions + Viterbi alignment, one jit.

    The serving step for the hubert cells: batch {"embeds": (B, S, D)} ->
    (paths (B, S), scores (B,)).
    """
    head = None  # built lazily inside jit from hmm params (closed over)

    def step(params, batch):
        x = batch["embeds"]
        # encoder forward reusing the model's loss-path stack
        from repro.models.transformer import _run_stack
        from repro.models.common import rms_norm
        h, _, _ = _run_stack(model.cfg, params, x.astype(model.cfg.dtype),
                             jnp.arange(x.shape[1]), collect_kv=False)
        h = rms_norm(h, params["ln_out"])
        logits = (h @ params["head"]).astype(jnp.float32)
        em = jax.nn.log_softmax(logits[..., :num_classes], axis=-1)

        def one(e):
            return flash_bs_viterbi(hmm.log_pi, hmm.log_A, e,
                                    beam_width=cfg.beam_width,
                                    parallelism=cfg.parallelism, lanes=None,
                                    chunk=cfg.chunk)
        return jax.vmap(one)(em)

    return step


__all__ = ["AlignmentConfig", "make_alignment_head", "make_e2e_align_step"]
