"""Continuous inflight batching: a slot-based streaming serving tier.

`StreamMux` buckets sessions by block size, so a session joining mid-flight
waits for its bucket and every session pays its own kernel dispatch.  This
module is the continuous-batching alternative (the shape modern inference
stacks use): an `InflightScheduler` owns a fixed pool of `max_slots` decode
slots backed by **one** persistent batched DP state, and every `step()`
advances all live slots by up to one block with a single batched kernel call
(`kernels.ops.viterbi_slot_step`, the fused batch-grid Pallas forward).

Sessions attach to a free slot at any block boundary and detach on finish.
The trick that makes join/leave free is the tropical identity: a slot with
`nfeed == 0` runs its whole block as identity steps (delta bit-identical,
psi rows the identity permutation), and a joining session's slot is re-seeded
*inside* the same jitted step via a `fresh` mask — so the traced computation
has one fixed shape `(S, block, K)` for the scheduler's lifetime and **no
retrace or recompile ever happens on join/leave** (pinned by the analysis
retrace battery).

Correctness is inherited, not re-proven: each slot's backpointer rows feed a
`core.online.SlotViterbiDecoder` — the same convergence-commit / forced-flush
algebra as `OnlineViterbiDecoder` — and the batched kernel is pinned
bit-identical per sequence to the single-sequence kernel, so every delivered
path is bit-identical to the looped unbatched `spec.run` oracle:

  * exact sessions (`max_lag=None`) may advance at any granularity —
    convergence commits are feed-boundary-independent;
  * bounded-lag sessions advance only in full `block`-sized feeds (plus the
    sub-block remainder at finish), replicating the forced-flush boundaries
    of `OnlineSpec(stream_chunk=block, max_lag=L).run` exactly.

Admission control runs against `core.spec.ResourceBudget`: each session is
costed at its worst-case window (`planner.online_session_bytes`) and, when
the remaining budget is short, degraded down the commit-lag ladder
(`planner.plan_admission`) before being queued; a session that cannot fit
the *total* budget even at the tightest rung is rejected outright.  The
queue is strict priority + FIFO within a class (head-of-line by design: a
queued head is never leapfrogged).

    sched = InflightScheduler(hmm.log_pi, hmm.log_A, max_slots=64, block=16)
    sid = sched.submit()
    sched.feed(sid, frames); sched.pump()
    prefix = sched.collect(sid)          # newly-final states, exactly once
    path, score = sched.finish(sid)      # full decode, frees the slot
"""

from __future__ import annotations

import itertools
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hmm import NEG_INF
from repro.core.online import SlotViterbiDecoder
from repro.core.planner import (AdmissionPlan, inflight_state_bytes,
                                online_session_bytes, plan_admission)
from repro.core.spec import OnlineSpec, ResourceBudget
from repro.kernels.ops import viterbi_slot_step

__all__ = ["InflightScheduler", "AdmissionRejected", "inflight_jit_fns"]


class AdmissionRejected(RuntimeError):
    """Session cannot fit the budget even at the tightest degradation rung."""


# ---------------------------------------------------------------------------
# The three jitted device touch-points.  All module-level with fixed traced
# shapes: joining/leaving sessions only ever change array *contents*, so each
# traces exactly once per (S, block, K) — the no-retrace battery monitors
# their cache sizes across join/step/leave churn.
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("bt",))
def _inflight_step(log_pi, log_A, em0, fresh, em, delta, nfeed, *, bt=8):
    """One batched block advance over every slot.

    `fresh[s]` re-seeds slot s's delta row to `log_pi + em0[s]` (frame 0 of a
    newly-attached session) before the block runs; `nfeed[s]` in [0, block]
    counts the real emission rows of `em[s]`, the rest (and every row of a
    free slot, nfeed 0) run as tropical-identity steps.
    """
    delta = jnp.where(fresh[:, None], log_pi[None, :] + em0, delta)
    return viterbi_slot_step(log_A, em, delta, nfeed, bt=bt)


@jax.jit
def _slot_row(delta, slot):
    """One slot's frontier delta row (pulled only at flush / forced-flush)."""
    return jax.lax.dynamic_index_in_dim(delta, slot, keepdims=False)


@jax.jit
def _mask_slot(delta, slot, keep):
    """Suppress one slot's frontier hypotheses inconsistent with a forced
    commit (same -inf accumulation as `OnlineViterbiDecoder`)."""
    row = jax.lax.dynamic_index_in_dim(delta, slot, keepdims=False)
    # flashlint: disable=FL007(slot forced-commit suppression, mirrors OnlineViterbiDecoder's annotated seam)
    row = jnp.where(keep, row, row + 4.0 * NEG_INF)
    return jax.lax.dynamic_update_index_in_dim(delta, row, slot, 0)


def inflight_jit_fns():
    """The jitted entry points the retrace battery guards."""
    return {"inflight_step": _inflight_step, "slot_row": _slot_row,
            "mask_slot": _mask_slot}


def _pct(xs: list[float], q: float) -> float:
    if not xs:
        return float("nan")
    return float(np.percentile(np.asarray(xs, np.float64), q))


class _Session:
    """Book-keeping for one submitted decode (queued, live, or done)."""

    __slots__ = ("sid", "priority", "requested_lag", "max_lag", "plan",
                 "slot", "dec", "buf", "buffered", "pending", "draining",
                 "seeded", "frames_in", "final",
                 "t_submit", "t_attach", "t_first_commit", "t_finish")

    def __init__(self, sid: int, priority: int, requested_lag: int | None,
                 t_submit: float):
        self.sid = sid
        self.priority = priority
        self.requested_lag = requested_lag
        self.max_lag = requested_lag          # replanned at admission
        self.plan: AdmissionPlan | None = None
        self.slot: int | None = None
        self.dec: SlotViterbiDecoder | None = None
        self.buf: list[np.ndarray] = []
        self.buffered = 0
        self.pending: list[np.ndarray] = []
        self.draining = False
        self.seeded = False
        self.frames_in = 0
        self.final: tuple[np.ndarray, float] | None = None
        self.t_submit = t_submit
        self.t_attach: float | None = None
        self.t_first_commit: float | None = None
        self.t_finish: float | None = None

    def take(self, n: int) -> np.ndarray:
        pending = (self.buf[0] if len(self.buf) == 1
                   else np.concatenate(self.buf, axis=0))
        out, rest = pending[:n], pending[n:]
        self.buf = [rest] if rest.shape[0] else []
        self.buffered = int(rest.shape[0])
        return out


class InflightScheduler:
    """A fixed pool of decode slots over one persistent batched DP state.

    Args:
      log_pi, log_A: the shared model.
      max_slots: slot-pool size S — the batch dimension of the persistent
        state; fixed for the scheduler's lifetime.
      block: frames advanced per slot per `step()` (the jitted time extent).
      budget: `ResourceBudget` (or raw byte count) capping the projected
        live session bytes across slots; None = admit while slots last.
      horizon: worst-case frames per session — bounds the exact decoder's
        commit window for admission costing, and `feed` enforces it.
      default_max_lag: `max_lag` for sessions that don't request their own.
      bt: time-tile of the batch-grid kernel.
      clock: monotonic-seconds source for SLO records (injectable in tests).
    """

    def __init__(self, log_pi, log_A, *, max_slots: int = 8, block: int = 16,
                 budget: ResourceBudget | int | None = None,
                 horizon: int = 4096, default_max_lag: int | None = None,
                 bt: int = 8, clock=time.monotonic):
        if max_slots < 1:
            raise ValueError(f"max_slots must be >= 1, got {max_slots}")
        if block < 1:
            raise ValueError(f"block must be >= 1, got {block}")
        self.log_pi = jnp.asarray(log_pi)
        self.log_A = jnp.asarray(log_A)
        self.K = int(self.log_A.shape[0])
        self.max_slots = int(max_slots)
        self.block = int(block)
        self.horizon = int(horizon)
        self.default_max_lag = default_max_lag
        self.bt = int(bt)
        if isinstance(budget, int):
            budget = ResourceBudget(memory_bytes=budget)
        self.budget = budget or ResourceBudget()
        self._clock = clock

        S, K, B = self.max_slots, self.K, self.block
        self._delta = jnp.zeros((S, K), jnp.float32)   # persistent DP carry
        self._em = np.zeros((S, B, K), np.float32)     # host staging, reused
        self._em0 = np.zeros((S, K), np.float32)
        self._fresh = np.zeros((S,), bool)
        self._nfeed = np.zeros((S,), np.int32)

        self._sessions: dict[int, _Session] = {}
        self._queue: list[_Session] = []               # arrival order
        self._free: list[int] = list(range(S - 1, -1, -1))
        self._admitted_bytes = 0
        self._ids = itertools.count()
        self._step_s: list[float] = []
        self.stats = {"opened": 0, "finished": 0, "steps": 0, "frames": 0,
                      "commits": 0, "degraded": 0, "queued_peak": 0,
                      "overflow_finishes": 0, "rejected": 0}

    # -- admission ----------------------------------------------------------
    def _remaining_bytes(self) -> int | None:
        cap = self.budget.memory_bytes
        return None if cap is None else cap - self._admitted_bytes

    def submit(self, *, max_lag: int | None | str = "default",
               priority: int = 0) -> int:
        """Open a session; admit it to a slot or queue it (FIFO per class).

        Raises `AdmissionRejected` when the session cannot fit the *total*
        budget even fully degraded — queueing it could never succeed.
        """
        requested = (self.default_max_lag if max_lag == "default"
                     else max_lag)
        cap = self.budget.memory_bytes
        if cap is not None and plan_admission(
                self.K, self.block, cap, requested_lag=requested,
                horizon=self.horizon) is None:
            self.stats["rejected"] += 1
            raise AdmissionRejected(
                f"session (max_lag={requested}) needs "
                f"{online_session_bytes(self.K, self.block, max_lag=8):,}B "
                f"even at the tightest ladder rung; total budget is {cap:,}B")
        sid = next(self._ids)
        sess = _Session(sid, int(priority), requested, self._clock())
        self._sessions[sid] = sess
        self.stats["opened"] += 1
        if not self._queue and self._free:
            plan = plan_admission(self.K, self.block, self._remaining_bytes(),
                                  requested_lag=requested,
                                  horizon=self.horizon)
            if plan is not None:
                self._attach(sess, plan)
                return sid
        self._queue.append(sess)
        self.stats["queued_peak"] = max(self.stats["queued_peak"],
                                        len(self._queue))
        return sid

    def _attach(self, sess: _Session, plan: AdmissionPlan) -> None:
        slot = self._free.pop()
        sess.slot = slot
        sess.plan = plan
        sess.max_lag = plan.max_lag
        if plan.degraded:
            self.stats["degraded"] += 1
        sess.dec = SlotViterbiDecoder(
            self.K, max_lag=plan.max_lag,
            frontier=lambda s=slot: _slot_row(self._delta, s),
            mask_scores=lambda keep, s=slot: self._apply_mask(s, keep))
        self._admitted_bytes += plan.state_bytes
        sess.t_attach = self._clock()

    def _apply_mask(self, slot: int, keep: np.ndarray) -> None:
        self._delta = _mask_slot(self._delta, slot, jnp.asarray(keep))

    def _drain_queue(self) -> None:
        # strict head-of-line: the best (priority, arrival) head either
        # fits (possibly degraded) or blocks the queue — FIFO within a
        # class is never violated by leapfrogging a smaller session.
        while self._queue and self._free:
            head = min(self._queue, key=lambda s: s.priority)  # stable: FIFO
            plan = plan_admission(self.K, self.block,
                                  self._remaining_bytes(),
                                  requested_lag=head.requested_lag,
                                  horizon=self.horizon)
            if plan is None:
                return
            self._queue.remove(head)
            self._attach(head, plan)

    # -- session I/O --------------------------------------------------------
    def _get(self, sid: int) -> _Session:
        try:
            return self._sessions[sid]
        except KeyError:
            raise KeyError(f"unknown session {sid}") from None

    def feed(self, sid: int, frames) -> dict:
        """Buffer (C, K) frames for a session (queued sessions buffer too).

        Buffering never advances the DP — call `pump()` (or `step()`) to run
        ready blocks; `collect(sid)` drains what became final.
        """
        sess = self._get(sid)
        if sess.final is not None:
            raise RuntimeError(f"session {sid} already finished")
        frames = np.asarray(frames, np.float32)
        if frames.ndim != 2 or frames.shape[1] != self.K:
            raise ValueError(f"expected (C, K={self.K}) frames, "
                             f"got {frames.shape}")
        if sess.frames_in + frames.shape[0] > self.horizon:
            raise ValueError(
                f"session {sid} exceeds horizon={self.horizon} frames "
                f"({sess.frames_in} fed + {frames.shape[0]} new); admission "
                f"costing is only sound up to the horizon")
        if frames.shape[0]:
            sess.buf.append(frames)
            sess.buffered += int(frames.shape[0])
            sess.frames_in += int(frames.shape[0])
        return {"buffered": sess.buffered, "queued": sess.slot is None,
                "lag": self.lag(sid)}

    def collect(self, sid: int) -> np.ndarray:
        """Drain this session's newly-final states (exactly-once delivery)."""
        sess = self._get(sid)
        if not sess.pending:
            return np.zeros((0,), np.int32)
        out = (sess.pending[0] if len(sess.pending) == 1
               else np.concatenate(sess.pending))
        sess.pending = []
        return out

    def lag(self, sid: int) -> int:
        """Fed-but-uncommitted frames (decoder window + feed buffer)."""
        sess = self._get(sid)
        dec_lag = sess.dec.lag if sess.dec is not None else 0
        return dec_lag + sess.buffered

    def n_committed(self, sid: int) -> int:
        sess = self._get(sid)
        return sess.dec.n_committed if sess.dec is not None else 0

    def session_spec(self, sid: int) -> OnlineSpec:
        """The `OnlineSpec` whose looped `run` this session is bit-identical
        to — the differential-oracle hook (`launch.loadtest.oracle_check`)."""
        sess = self._get(sid)
        return OnlineSpec(stream_chunk=self.block, max_lag=sess.max_lag)

    # -- the batched advance ------------------------------------------------
    def _consume_now(self, sess: _Session) -> int:
        """Frames this slot eats in the next step (0 = sit out as identity).

        Exact sessions advance greedily (commits are feed-boundary
        independent); bounded-lag sessions only ever advance in full
        `block`-sized feeds — plus the sub-block remainder while draining —
        so their forced-flush boundaries replicate the oracle's.
        """
        b = sess.buffered
        if not b or sess.slot is None or sess.final is not None:
            return 0
        if sess.max_lag is None:
            # fresh slot: +1 because the seed frame costs no kernel row
            return min(b, self.block + (0 if sess.seeded else 1))
        # bounded-lag: consume in the oracle's chunk units — exactly `block`
        # frames per feed (the seed frame counts toward the first chunk),
        # sub-block remainder only as the final feed while draining
        if b >= self.block:
            return self.block
        return b if sess.draining else 0

    def step(self) -> dict:
        """Advance every ready slot by up to one block: one kernel call.

        Slots with nothing ready ride along as tropical-identity steps —
        their delta comes back bit-identical.  Returns counters.
        """
        plans: list[tuple[_Session, int]] = []
        for sess in self._sessions.values():
            c = self._consume_now(sess)
            if c:
                plans.append((sess, c))
        if not plans:
            return {"advanced": 0, "frames": 0, "committed": 0}
        t0 = self._clock()
        for sess, c in plans:
            s = sess.slot
            frames = sess.take(c)
            if not sess.seeded:
                self._em0[s] = frames[0]
                self._fresh[s] = True
                rows = frames[1:]
            else:
                rows = frames
            n = int(rows.shape[0])
            if n:
                self._em[s, :n] = rows
            self._nfeed[s] = n
        psi, self._delta = _inflight_step(
            self.log_pi, self.log_A, jnp.asarray(self._em0),
            jnp.asarray(self._fresh), jnp.asarray(self._em), self._delta,
            jnp.asarray(self._nfeed), bt=self.bt)
        psi_np = np.asarray(psi)          # one batched transfer per step
        frames_run = 0
        committed = 0
        for sess, c in plans:
            s = sess.slot
            if not sess.seeded:
                sess.seeded = True
                sess.dec.seed()
                self._fresh[s] = False
            n = int(self._nfeed[s])
            self._nfeed[s] = 0
            frames_run += c
            if n:
                out = sess.dec.ingest(psi_np[s, :n])
                if out.shape[0]:
                    sess.pending.append(out)
                    committed += int(out.shape[0])
                    if sess.t_first_commit is None:
                        sess.t_first_commit = self._clock()
        self._step_s.append(self._clock() - t0)
        self.stats["steps"] += 1
        self.stats["frames"] += frames_run
        self.stats["commits"] += committed
        return {"advanced": len(plans), "frames": frames_run,
                "committed": committed}

    def pump(self) -> int:
        """Step while any live slot has a full block buffered; returns steps."""
        n = 0
        while any(s.slot is not None and s.final is None
                  and s.buffered >= self.block
                  for s in self._sessions.values()):
            self.step()
            n += 1
        return n

    # -- finish / detach ----------------------------------------------------
    def finish(self, sid: int) -> tuple[np.ndarray, float]:
        """Drain, flush, detach; returns (full path, score).  Idempotent.

        A session finished while still *queued* (budget held it out of the
        pool the whole time) is decoded on the spot with its own unbatched
        streaming decoder — same algorithm, same oracle — so the tier stays
        live under overload; counted in `stats["overflow_finishes"]`.
        """
        sess = self._get(sid)
        if sess.final is not None:
            return sess.final
        if sess.slot is None:
            return self._overflow_finish(sess)
        sess.draining = True
        while sess.buffered:
            self.step()
        tail, score = sess.dec.flush()
        if tail.shape[0]:
            sess.pending.append(tail)
        sess.final = (sess.dec.path, score)
        self._detach(sess)
        return sess.final

    def _overflow_finish(self, sess: _Session) -> tuple[np.ndarray, float]:
        from repro.core.online import OnlineViterbiDecoder
        self._queue.remove(sess)
        dec = OnlineViterbiDecoder(self.log_pi, self.log_A,
                                   max_lag=sess.requested_lag, bt=self.bt)
        frames = (np.concatenate(sess.buf, axis=0) if sess.buf
                  else np.zeros((0, self.K), np.float32))
        sess.buf, sess.buffered = [], 0
        out: list[np.ndarray] = []
        for i in range(0, frames.shape[0], self.block):
            out.append(dec.feed(frames[i:i + self.block]))
        tail, score = dec.flush()
        out.append(tail)
        seg = np.concatenate(out) if out else np.zeros((0,), np.int32)
        if seg.shape[0]:
            sess.pending.append(seg)
        sess.final = (dec.path, score)
        sess.t_finish = self._clock()
        self.stats["finished"] += 1
        self.stats["overflow_finishes"] += 1
        return sess.final

    def _detach(self, sess: _Session) -> None:
        self._free.append(sess.slot)
        self._admitted_bytes -= sess.plan.state_bytes
        sess.slot = None
        sess.t_finish = self._clock()
        self.stats["finished"] += 1
        self._drain_queue()

    # -- observability ------------------------------------------------------
    def live_sessions(self) -> list[int]:
        return [s.sid for s in self._sessions.values()
                if s.slot is not None and s.final is None]

    def queued_sessions(self) -> list[int]:
        return [s.sid for s in self._queue]

    def admitted_bytes(self) -> int:
        """Projected worst-case bytes of the currently-admitted sessions
        (the quantity admission control holds under the budget)."""
        return self._admitted_bytes

    def live_state_bytes(self) -> int:
        """Actual live host-side bytes right now: decoder windows + buffers."""
        total = 0
        for s in self._sessions.values():
            if s.slot is not None and s.final is None:
                total += s.dec.live_state_bytes() + s.buffered * self.K * 4
        return total

    def device_state_bytes(self) -> int:
        """Fixed device-side footprint of the slot pool (PV104's model)."""
        return inflight_state_bytes(self.K, self.block, self.max_slots)

    def slo_report(self) -> dict:
        """Per-step and per-session service-level metrics.

        block latency = wall seconds per `step()` (kernel + commit scan);
        commit lag = fed-but-unfinal frames (peak per session).
        """
        done = [s for s in self._sessions.values() if s.final is not None]
        q_wait = [s.t_attach - s.t_submit for s in done
                  if s.t_attach is not None]
        first = [s.t_first_commit - s.t_submit for s in done
                 if s.t_first_commit is not None]
        comp = [s.t_finish - s.t_submit for s in done
                if s.t_finish is not None]
        peak_lag = [s.dec.stats["peak_lag"] for s in done if s.dec is not None]
        forced = sum(s.dec.stats["forced"] for s in done if s.dec is not None)
        return {
            "block_latency_s": {"count": len(self._step_s),
                                "p50": _pct(self._step_s, 50),
                                "p99": _pct(self._step_s, 99)},
            "queue_wait_s": {"p50": _pct(q_wait, 50), "p99": _pct(q_wait, 99)},
            "first_commit_s": {"p50": _pct(first, 50), "p99": _pct(first, 99)},
            "completion_s": {"p50": _pct(comp, 50), "p99": _pct(comp, 99)},
            "commit_lag": {"peak_p50": _pct([float(x) for x in peak_lag], 50),
                           "peak_p99": _pct([float(x) for x in peak_lag], 99),
                           "forced_flushes": int(forced)},
            "stats": dict(self.stats),
        }
