"""Batched request scheduler for the serving examples.

Continuous-batching-lite: requests queue up, the scheduler packs up to
`max_batch` compatible requests (same HMM / model), pads sequences to the
bucket boundary, runs one batched decode, and fans results back out.  Buckets
keep jit cache hits high (one compile per bucket, not per length).

The decode function receives the true lengths alongside the padded batch:
``decode_batch_fn(padded (B, Tb, K), lengths (B,) int32) -> (paths, scores)``.
Length-aware decoders (``core.viterbi_decode_batch``) mask pad frames as
tropical-identity steps, so every request's path and score are bit-identical
to an unbatched decode of its unpadded payload — padding is a pure throughput
trick, never an approximation.
"""

from __future__ import annotations

import dataclasses
import itertools
import time
from collections import deque
from typing import Any, Callable

import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    payload: Any                    # (T, K) emissions or token prompt
    arrival: float = 0.0
    result: Any = None
    done: bool = False


class BatchScheduler:
    """Packs requests into padded buckets and runs one batched decode.

    `decode_batch_fn` is either the raw callable contract above, or a
    `core.ViterbiDecoder` — the scheduler then drives its `decode_batch`
    (the decoder owns jit caching per bucket shape and the lengths contract).
    """

    def __init__(self, decode_batch_fn, max_batch: int = 8,
                 buckets: tuple[int, ...] = (128, 256, 512, 1024, 2048)):
        from repro.core import ViterbiDecoder
        if isinstance(decode_batch_fn, ViterbiDecoder):
            decode_batch_fn = decode_batch_fn.decode_batch
        self.fn: Callable = decode_batch_fn
        self.max_batch = max_batch
        self.buckets = sorted(buckets)
        self.queue: deque[Request] = deque()
        self._next_id = itertools.count()
        self.stats = {"batches": 0, "requests": 0, "padded_frac": []}

    def submit(self, payload) -> Request:
        req = Request(rid=next(self._next_id), payload=payload,
                      arrival=time.monotonic())
        self.queue.append(req)
        return req

    def _bucket(self, length: int) -> int:
        for b in self.buckets:
            if length <= b:
                return b
        return self.buckets[-1]

    def step(self) -> list[Request]:
        """Run one batch; returns completed requests."""
        if not self.queue:
            return []
        first = self.queue[0]
        bucket = self._bucket(len(first.payload))
        batch: list[Request] = []
        rest: deque[Request] = deque()
        while self.queue and len(batch) < self.max_batch:
            r = self.queue.popleft()
            if self._bucket(len(r.payload)) == bucket:
                batch.append(r)
            else:
                rest.append(r)
        self.queue.extendleft(reversed(rest))

        lens = np.asarray([len(r.payload) for r in batch], np.int32)
        K = batch[0].payload.shape[-1]
        padded = np.zeros((len(batch), bucket, K), np.float32)
        for i, r in enumerate(batch):
            padded[i, :lens[i]] = r.payload  # pad tail masked by the decoder
        paths, scores = self.fn(padded, lens)
        for i, r in enumerate(batch):
            r.result = (np.asarray(paths[i][:lens[i]]), float(scores[i]))
            r.done = True
        self.stats["batches"] += 1
        self.stats["requests"] += len(batch)
        self.stats["padded_frac"].append(1 - np.mean(lens) / bucket)
        return batch

    def drain(self) -> list[Request]:
        done = []
        while self.queue:
            done.extend(self.step())
        return done


__all__ = ["Request", "BatchScheduler"]
