"""Optimizers and distributed-optimization utilities (pure JAX)."""

from .adamw import (AdamWConfig, schedule, init_state, update, global_norm,
                    zero1_specs, opt_state_specs)
from .compression import quantize, dequantize, ef_accumulate, init_ef_state

__all__ = ["AdamWConfig", "schedule", "init_state", "update", "global_norm",
           "zero1_specs", "opt_state_specs", "quantize", "dequantize",
           "ef_accumulate", "init_ef_state"]
