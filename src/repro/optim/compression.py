"""Gradient compression with error feedback (int8 accumulation buffers).

Used by the gradient-accumulation loop: microbatch gradients are accumulated
into int8 buffers (per-tensor absmax scaling) with an error-feedback residual,
cutting the accumulation-buffer footprint 4x vs fp32 — the distributed-
optimization trick applied where it is honest under XLA SPMD (the cross-device
reduce itself is compiler-inserted; what we control is the on-chip buffer the
reduce consumes, and the dtype it reduces in when `reduce_dtype` is set).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize(x: jax.Array):
    """Symmetric per-tensor int8 quantisation. Returns (q, scale)."""
    amax = jnp.max(jnp.abs(x)).astype(jnp.float32)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127
                 ).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array):
    return q.astype(jnp.float32) * scale


def ef_accumulate(acc_q, acc_scale, residual, grad):
    """Error-feedback accumulate: acc += grad, storing acc in int8.

    Returns (new_acc_q, new_scale, new_residual).
    """
    full = dequantize(acc_q, acc_scale) + grad.astype(jnp.float32) + residual
    q, scale = quantize(full)
    new_res = full - dequantize(q, scale)
    return q, scale, new_res


def init_ef_state(params):
    return {
        "q": jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.int8), params),
        "scale": jax.tree_util.tree_map(
            lambda p: jnp.zeros((), jnp.float32), params),
        "residual": jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params),
    }


__all__ = ["quantize", "dequantize", "ef_accumulate", "init_ef_state"]
