"""AdamW in pure JAX with ZeRO-1 optimizer-state sharding.

The first/second-moment buffers carry *additional* sharding over the data axis
(ZeRO-1): `zero1_specs` takes each parameter's own PartitionSpec and shards the
largest still-replicated axis across ("pod","data") when divisible.  For the
236B config this is the difference between fitting and not fitting a pod
(AdamW fp32 moments are 8 bytes/param on top of the bf16 weights).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def schedule(cfg: AdamWConfig, step):
    """Linear warmup + cosine decay."""
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


def init_state(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree_util.tree_map(zeros, params),
            "v": jax.tree_util.tree_map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def update(cfg: AdamWConfig, grads, state, params):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh, vh = m / b1c, v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    out = jax.tree_util.tree_map(upd, grads, state["m"], state["v"], params)
    new_params = jax.tree_util.tree_map(lambda o: o[0], out,
                                        is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree_util.tree_map(lambda o: o[1], out,
                                   is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree_util.tree_map(lambda o: o[2], out,
                                   is_leaf=lambda x: isinstance(x, tuple))
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, {"m": new_m, "v": new_v, "step": step}, metrics


def zero1_specs(param_spec_tree, param_shape_tree, data_axes=("data",),
                data_size: int = 16):
    """ZeRO-1: shard each moment buffer's largest replicated axis over data.

    param_spec_tree / param_shape_tree: matching pytrees of PartitionSpec and
    shapes.  Returns the moment-buffer spec tree.
    """
    axis_name = data_axes if len(data_axes) > 1 else data_axes[0]

    def one(spec, shape):
        spec_t = tuple(spec) + (None,) * (len(shape) - len(spec))
        cand, size = None, 0
        for i, (s, n) in enumerate(zip(spec_t, shape)):
            if s is None and n % data_size == 0 and n > size:
                cand, size = i, n
        if cand is None:
            return P(*spec_t)
        new = list(spec_t)
        new[cand] = axis_name
        return P(*new)

    shapes = jax.tree_util.tree_map(lambda s: s.shape if hasattr(s, "shape") else s,
                                    param_shape_tree)
    return jax.tree_util.tree_map(one, param_spec_tree, shapes,
                                  is_leaf=lambda x: isinstance(x, P))


def opt_state_specs(param_spec_tree, param_shape_tree, data_axes=("data",),
                    data_size: int = 16):
    mom = zero1_specs(param_spec_tree, param_shape_tree, data_axes, data_size)
    return {"m": mom, "v": mom, "step": P()}


__all__ = ["AdamWConfig", "schedule", "init_state", "update", "global_norm",
           "zero1_specs", "opt_state_specs"]
