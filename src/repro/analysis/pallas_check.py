"""flashprove pass 2 — static VMEM residency + tile alignment for Pallas kernels.

`kernels/ops.py` guards the TPU path at *runtime* (`_kernel_fits` falls back
to XLA when a config is too big) — but the raw kernels in `viterbi_dp.py`,
`beam_stream.py` and `tropical.py` will happily compile a `pallas_call`
whose blocks cannot fit VMEM, and the failure mode on hardware is a
compile-time or on-device OOM long after planning said yes.  This pass makes
that a lint failure instead.

It does not parse source.  Each kernel entry point is traced
(`jax.make_jaxpr`, `interpret=True` — tracing never executes the kernel) at
every tile config the decode stack can reach (spec defaults and the tile
ladder `ops.tropical_matmul` picks, across the K grid the planner serves),
and the `pallas_call` equations are read straight out of the jaxpr: the
`GridMapping` carries every declared `BlockSpec`'s block shape, the array
aval it blocks, and the traced index map.  From those declarations:

  * **Residency (PV202).**  Per grid step, each operand holds one block of
    ``prod(block_shape) x itemsize`` bytes in VMEM.  An index map whose
    output *moves* with the grid marks a streamed block — the pipeline
    double-buffers it (x2) to overlap the next DMA with compute; a constant
    index map marks a revisited/resident block (x1).  Scratch shapes are
    VMEM by construction.  The sum must fit `DEFAULT_VMEM_BUDGET`
    (= the 12 MiB working limit `ops._kernel_fits` enforces at runtime —
    the two bounds are deliberately the same number).

  * **Tile alignment (PV201).**  TPU vector memory tiles f32 as (8, 128):
    a block whose lane (last) dimension is not a multiple of 128, or whose
    sublane dimension is not a multiple of 8, pads every tile it touches —
    silent bandwidth loss.  Dimensions that cover the whole (unpadded)
    array axis are exempt: the array itself is that shape, so the layout
    cost is the data's, not the blocking's.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .findings import Finding, ProveReport
from .jaxpr_check import iter_eqns

__all__ = [
    "DEFAULT_VMEM_BUDGET", "LANE", "SUBLANE", "BlockInfo", "KernelSummary",
    "harvest_pallas_calls", "kernel_configs", "check_pallas",
]

#: Per-grid-step VMEM budget — matches `ops._kernel_fits`' runtime limit.
DEFAULT_VMEM_BUDGET = 12 * 2**20
#: f32 VMEM tile: (sublane, lane).
SUBLANE, LANE = 8, 128


@dataclasses.dataclass(frozen=True)
class BlockInfo:
    """One declared BlockSpec as read back from a traced `pallas_call`."""
    label: str                  # "in[0]", "out[1]", "scratch[0]"
    block_shape: tuple[int, ...]
    array_shape: tuple[int, ...]
    dtype: str
    streamed: bool              # index map moves with the grid

    @property
    def block_bytes(self) -> int:
        return (math.prod(self.block_shape)
                * np.dtype(self.dtype).itemsize)

    @property
    def resident_bytes(self) -> int:
        """VMEM held per grid step: streamed blocks are double-buffered."""
        return self.block_bytes * (2 if self.streamed else 1)


@dataclasses.dataclass(frozen=True)
class KernelSummary:
    """One `pallas_call` equation: its grid and every operand's residency."""
    grid: tuple[int, ...]
    blocks: tuple[BlockInfo, ...]

    @property
    def vmem_bytes(self) -> int:
        return sum(b.resident_bytes for b in self.blocks)


def _index_map_moves(block_mapping) -> bool:
    """True when the block's index map output depends on the grid position.

    Decided by evaluating the traced index map at two grid corners — no
    structural guessing about literals vs. vars.
    """
    closed = block_mapping.index_map_jaxpr
    n = len(closed.jaxpr.invars)
    zeros = [jnp.int32(0)] * n
    probe = [jnp.int32(3 + i) for i in range(n)]
    at0 = jax.core.eval_jaxpr(closed.jaxpr, closed.consts, *zeros)
    at1 = jax.core.eval_jaxpr(closed.jaxpr, closed.consts, *probe)
    return any(int(a) != int(b) for a, b in zip(at0, at1))


def harvest_pallas_calls(closed) -> list[KernelSummary]:
    """Every `pallas_call` in a traced jaxpr, as `KernelSummary` objects."""
    out = []
    for eqn in iter_eqns(getattr(closed, "jaxpr", closed)):
        if eqn.primitive.name != "pallas_call":
            continue
        gm = eqn.params["grid_mapping"]
        blocks: list[BlockInfo] = []
        n_in = len(eqn.invars)
        n_out = len(eqn.outvars)
        for i, bm in enumerate(gm.block_mappings):
            label = f"in[{i}]" if i < n_in else f"out[{i - n_in}]"
            sd = bm.array_shape_dtype
            blocks.append(BlockInfo(
                label=label,
                block_shape=tuple(int(d) for d in bm.block_shape),
                array_shape=tuple(int(d) for d in sd.shape),
                dtype=np.dtype(sd.dtype).name,
                streamed=_index_map_moves(bm)))
        n_scratch = getattr(gm, "num_scratch_operands", 0)
        if n_scratch:
            body = eqn.params["jaxpr"]
            for j, v in enumerate(body.invars[-n_scratch:]):
                aval = v.aval
                shape = tuple(int(d) for d in getattr(aval, "shape", ()))
                blocks.append(BlockInfo(
                    label=f"scratch[{j}]", block_shape=shape,
                    array_shape=shape,
                    dtype=np.dtype(getattr(aval, "dtype", jnp.float32)).name,
                    streamed=False))
        out.append(KernelSummary(
            grid=tuple(int(g) for g in gm.grid), blocks=tuple(blocks)))
    if not out:
        raise ValueError("traced entry contains no pallas_call")
    return out


def _alignment_findings(subject: str, block: BlockInfo) -> list[Finding]:
    bs, arr = block.block_shape, block.array_shape
    found = []

    def _bad(axis_name: str, dim: int, full: int, mult: int) -> None:
        found.append(Finding(
            "PV201", subject,
            f"{block.label} block {bs} of {block.dtype}{list(arr)}: "
            f"{axis_name} dimension {dim} is neither a multiple of {mult} "
            f"nor the full array axis ({full}); every tile it touches is "
            f"padded on TPU"))

    if not bs:
        return found
    lane, full_lane = bs[-1], arr[-1] if arr else bs[-1]
    if lane % LANE and lane != full_lane:
        _bad("lane", lane, full_lane, LANE)
    if len(bs) >= 2:
        sub, full_sub = bs[-2], arr[-2] if len(arr) >= 2 else bs[-2]
        # sublane 1 is the squeeze/batch-axis idiom (a grid axis indexes
        # single rows); the layout unit that matters is the lane dim.
        if sub % SUBLANE and sub != full_sub and sub != 1:
            _bad("sublane", sub, full_sub, SUBLANE)
    return found


def _check_entry(subject: str, trace: Callable[[], object],
                 budget: int, report: ProveReport) -> None:
    try:
        closed = jax.make_jaxpr(trace)()
    except Exception as e:
        report.findings.append(Finding(
            "PV202", subject, f"trace error {e!r}"))
        return
    for ki, summary in enumerate(harvest_pallas_calls(closed)):
        ksub = subject if ki == 0 else f"{subject}#{ki}"
        for block in summary.blocks:
            report.findings.extend(_alignment_findings(ksub, block))
        vmem = summary.vmem_bytes
        report.stats[ksub] = {
            "grid": list(summary.grid),
            "vmem_bytes": vmem,
            "budget_bytes": budget,
            "blocks": {b.label: {"block_shape": list(b.block_shape),
                                 "dtype": b.dtype,
                                 "streamed": b.streamed,
                                 "resident_bytes": b.resident_bytes}
                       for b in summary.blocks},
        }
        if vmem > budget:
            worst = max(summary.blocks, key=lambda b: b.resident_bytes)
            report.findings.append(Finding(
                "PV202", ksub,
                f"per-grid-step VMEM residency {vmem:,}B exceeds budget "
                f"{budget:,}B (largest: {worst.label} "
                f"{worst.block_shape} {worst.dtype}"
                f"{' x2 streamed' if worst.streamed else ''})"))
    report.checks.append(subject)


def kernel_configs(deep: bool = False) -> list[tuple[str, Callable[[], object]]]:
    """(subject, thunk) per kernel entry x reachable tile config.

    Configs come from the decode stack, not thin air: `FusedSpec.bt` (the
    only bt the planner's typed specs carry), the K ladder the fused/online
    Pallas path accepts (`ops._kernel_fits` requires K % 128 == 0; --deep
    walks it up to the largest config that still passes the runtime guard),
    `ops.beam_step`'s B/chunk defaults, and both tile corners
    `ops.tropical_matmul`'s shape-adaptive ladder can pick.
    """
    from repro.core.spec import FusedSpec
    from repro.kernels import beam_stream, ops, tropical, viterbi_dp

    bt = FusedSpec().bt
    f32 = jnp.float32
    ks = (128, 512, 1024) if deep else (128, 512)
    configs: list[tuple[str, Callable[[], object]]] = []

    def _fused(K: int, bt: int, B: int = 2):
        T = 4 * bt
        A = jnp.zeros((K, K), f32)
        em = jnp.zeros((B, T, K), f32)
        d0 = jnp.zeros((B, K), f32)
        return lambda: viterbi_dp.viterbi_forward_batch(
            A, em, d0, bt=bt, interpret=True)

    for K in ks:
        configs.append((f"pallas:viterbi_dp.viterbi_forward_batch"
                        f"[K={K},bt={bt}]", _fused(K, bt)))

    def _beam(K: int, B: int, chunk: int):
        A = jnp.zeros((K, K), f32)
        em = jnp.zeros((K,), f32)
        sc = jnp.zeros((B,), f32)
        st = jnp.zeros((B,), jnp.int32)
        return lambda: beam_stream.beam_step(
            A, em, sc, st, chunk=chunk, interpret=True)

    for B in (128, 256):
        configs.append((f"pallas:beam_stream.beam_step[K=512,B={B},chunk=256]",
                        _beam(512, B, 256)))

    def _trop(I: int, K: int, J: int):
        a = jnp.zeros((I, K), f32)
        b = jnp.zeros((K, J), f32)
        return lambda: ops.tropical_matmul(a, b, interpret=True)

    # both corners of ops.tropical_matmul's tile ladder:
    # small -> (bi,bk,bj)=(8,8,128), large -> (64,16,256).
    configs.append(("pallas:tropical.tropical_matmul[tiles=8x8x128]",
                    _trop(32, 8, 128)))
    configs.append(("pallas:tropical.tropical_matmul[tiles=64x16x256]",
                    _trop(128, 128, 512)))
    return configs


def check_pallas(quick: bool = False, deep: bool = False,
                 budget: int = DEFAULT_VMEM_BUDGET) -> ProveReport:
    """Verify every kernel x reachable tile config fits VMEM and the tile
    grid.  ``quick`` keeps one config per kernel; ``deep`` extends the K
    ladder to the runtime guard's edge."""
    report = ProveReport()
    configs = kernel_configs(deep=deep)
    if quick:
        seen: set[str] = set()
        kept = []
        for subject, thunk in configs:
            key = subject.split("[")[0]
            if key in seen:
                report.skipped.append(subject)
                continue
            seen.add(key)
            kept.append((subject, thunk))
        configs = kept
    for subject, thunk in configs:
        _check_entry(subject, thunk, budget, report)
    return report
