"""Trace-time contract checker for every registered `DecodeSpec`.

Three families of contracts, none of which execute a decode on real data:

  * **Shape/dtype contracts** — every offline (jittable) spec is traced with
    `jax.eval_shape` over a (K, T) grid, and every batchable spec over a
    (K, T, B) grid with ragged lengths: paths must be int32 of the right
    shape, scores float32, nothing may be weakly typed, and float64 must not
    leak anywhere into the outputs.

  * **Memory cross-check** — the planner's analytic `decoder_state_bytes`
    model is what the budget -> plan ladder trusts (`core/planner.py`); if a
    kernel change makes the compiled program allocate asymptotically more
    than the model claims, the ladder silently under-budgets.  For each spec
    and grid point we compile the decode (`jit(...).lower(...).compile()`)
    and assert ``memory_analysis().temp_size_in_bytes <= model x tolerance``
    with the per-method tolerances pinned in `MEMORY_TOLERANCE`.  The
    tolerances absorb a known, measured constant: XLA's CPU backend
    materialises whole wavefront transients that the TPU pipeline streams
    (flash/flash_bs carry the largest pinned ratio for that reason); the
    gate exists to catch *drift* beyond that envelope, and the compiled
    module is also cross-parsed with `launch/hlo_cost.py` as a sanity check.
    The tier-2 `jaxpr_check` pass tightens this same model from the other
    side: `planner.crosscheck_state_bytes` bounds the *IR-derived* DP-state
    bytes (liveness over the traced jaxpr, allocator out of the picture)
    at ~1x instead of the 8-96x allocator tolerances pinned here.

  * **Streaming contracts** — the online decoders are stateful host loops
    (not traceable), so their contract is checked live on a tiny stream:
    committed paths are int32 and complete, and the *measured* peak
    `live_state_bytes()` never exceeds the planner model (the model is a
    worst-case bound, so exceeding it means the cost model drifted from the
    implementation).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.spec import (AssocSpec, BeamStaticMPSpec, BeamStaticSpec,
                             CheckpointSpec, DecodeSpec, FlashBSSpec,
                             FlashSpec, FusedSpec, OnlineBeamSpec, OnlineSpec,
                             SPEC_BY_METHOD, VanillaSpec)
from repro.core.planner import spec_state_bytes

__all__ = [
    "TRACEABLE_SPECS", "STREAMING_SPECS", "SHAPE_GRID", "BATCH_GRID",
    "MEMORY_GRID", "MEMORY_TOLERANCE", "ContractError", "ContractReport",
    "check_contracts", "check_shape_contracts", "check_memory_contracts",
    "check_streaming_contracts", "compiled_state_bytes",
]

#: One default-constructed instance per registered offline (jittable) method.
TRACEABLE_SPECS: tuple[DecodeSpec, ...] = (
    VanillaSpec(), CheckpointSpec(), FlashSpec(), FlashBSSpec(),
    BeamStaticSpec(), BeamStaticMPSpec(), AssocSpec(), FusedSpec())

#: The stateful streaming methods (checked live, not traced).
STREAMING_SPECS: tuple[DecodeSpec, ...] = (
    OnlineSpec(stream_chunk=16), OnlineBeamSpec(stream_chunk=16))

SHAPE_GRID: tuple[tuple[int, int], ...] = ((8, 16), (24, 64), (64, 256))
BATCH_GRID: tuple[tuple[int, int, int], ...] = ((16, 32, 3), (24, 48, 5))
MEMORY_GRID: tuple[tuple[int, int], ...] = ((24, 64), (64, 256))

#: Pinned ceilings for compiled_temp / model, per method, over MEMORY_GRID
#: (measured on the CPU backend at jax 0.4.37, ~2x headroom; see module
#: docstring for why flash's wavefront transients dominate off-TPU).
MEMORY_TOLERANCE: dict[str, float] = {
    "vanilla": 8.0,
    "checkpoint": 16.0,
    "flash": 96.0,
    "flash_bs": 64.0,
    "beam_static": 4.0,
    "beam_static_mp": 96.0,
    "assoc": 64.0,
    "fused": 8.0,
}


class ContractError(AssertionError):
    """A decode-stack contract does not hold."""


@dataclasses.dataclass
class ContractReport:
    checks: list[str] = dataclasses.field(default_factory=list)
    failures: list[str] = dataclasses.field(default_factory=list)
    skipped: list[str] = dataclasses.field(default_factory=list)
    #: (method, K, T) -> compiled_temp / model ratio from the memory pass.
    memory_ratios: dict[tuple[str, int, int], float] = dataclasses.field(
        default_factory=dict)

    @property
    def ok(self) -> bool:
        return not self.failures

    def raise_if_failed(self) -> None:
        if self.failures:
            raise ContractError(
                f"{len(self.failures)} contract violation(s):\n  "
                + "\n  ".join(self.failures))


def _abstract_hmm(K: int, T: int):
    return (jax.ShapeDtypeStruct((K,), jnp.float32),
            jax.ShapeDtypeStruct((K, K), jnp.float32),
            jax.ShapeDtypeStruct((T, K), jnp.float32))


def _expect(report: ContractReport, what: str, cond: bool, detail: str):
    if cond:
        report.checks.append(what)
    else:
        report.failures.append(f"{what}: {detail}")


def _check_pair(report: ContractReport, label: str, out, path_shape,
                score_shape):
    path, score = out
    _expect(report, f"{label} path", tuple(path.shape) == tuple(path_shape)
            and path.dtype == jnp.int32
            and not getattr(path, "weak_type", False),
            f"got shape={tuple(path.shape)} dtype={path.dtype} "
            f"weak_type={getattr(path, 'weak_type', False)}; want "
            f"{tuple(path_shape)} int32 strong")
    _expect(report, f"{label} score",
            tuple(score.shape) == tuple(score_shape)
            and score.dtype == jnp.float32
            and not getattr(score, "weak_type", False),
            f"got shape={tuple(score.shape)} dtype={score.dtype} "
            f"weak_type={getattr(score, 'weak_type', False)}; want "
            f"{tuple(score_shape)} float32 strong")


# ---------------------------------------------------------------------------
# Shape/dtype contracts (pure tracing)
# ---------------------------------------------------------------------------

def check_shape_contracts(specs: Sequence[DecodeSpec] = TRACEABLE_SPECS,
                          grid: Sequence[tuple[int, int]] = SHAPE_GRID,
                          batch_grid: Sequence[tuple[int, int, int]]
                          = BATCH_GRID,
                          report: ContractReport | None = None
                          ) -> ContractReport:
    report = report if report is not None else ContractReport()
    for spec in specs:
        for K, T in grid:
            label = f"eval_shape[{spec.method} K={K} T={T}]"
            pi, A, em = _abstract_hmm(K, T)
            try:
                out = jax.eval_shape(spec.run, pi, A, em)
            except Exception as e:  # tracing itself must not fail
                report.failures.append(f"{label}: trace error {e!r}")
                continue
            _check_pair(report, label, out, (T,), ())
        if spec.batch_method is None:
            continue
        from repro.core.batch import viterbi_decode_batch
        for K, T, B in batch_grid:
            label = f"eval_shape[{spec.method} batch K={K} T={T} B={B}]"
            pi, A, em = _abstract_hmm(K, T)
            em_b = jax.ShapeDtypeStruct((B, T, K), jnp.float32)
            # ragged on purpose: every row a different true length
            lengths = jnp.asarray([(i % T) + 1 for i in range(B)], jnp.int32)
            tun = spec.batch_tunables()

            def run_batch(em_, pi_, A_, spec=spec, lengths=lengths, tun=tun):
                return viterbi_decode_batch(em_, pi_, A_, lengths,
                                            method=spec.batch_method, **tun)
            try:
                out = jax.eval_shape(run_batch, em_b, pi, A)
            except Exception as e:
                report.failures.append(f"{label}: trace error {e!r}")
                continue
            _check_pair(report, label, out, (B, T), (B,))
    return report


# ---------------------------------------------------------------------------
# Memory cross-check (compile, never execute)
# ---------------------------------------------------------------------------

def compiled_state_bytes(spec: DecodeSpec, K: int, T: int) -> int | None:
    """Temp bytes the compiled single-sequence decode allocates, or None if
    this jax/backend does not expose `memory_analysis()`."""
    pi, A, em = _abstract_hmm(K, T)
    compiled = jax.jit(spec.run).lower(pi, A, em).compile()
    try:
        mem = compiled.memory_analysis()
        return int(mem.temp_size_in_bytes)
    except (AttributeError, NotImplementedError, jax.errors.JaxRuntimeError):
        return None


def check_memory_contracts(specs: Sequence[DecodeSpec] = TRACEABLE_SPECS,
                           grid: Sequence[tuple[int, int]] = MEMORY_GRID,
                           report: ContractReport | None = None
                           ) -> ContractReport:
    report = report if report is not None else ContractReport()
    from repro.launch.hlo_cost import analyze_text
    for spec in specs:
        tol = MEMORY_TOLERANCE.get(spec.method)
        if tol is None:
            report.failures.append(
                f"memory[{spec.method}]: no pinned tolerance in "
                f"MEMORY_TOLERANCE — add one")
            continue
        for K, T in grid:
            label = f"memory[{spec.method} K={K} T={T}]"
            pi, A, em = _abstract_hmm(K, T)
            compiled = jax.jit(spec.run).lower(pi, A, em).compile()
            try:
                temp = int(compiled.memory_analysis().temp_size_in_bytes)
            except (AttributeError, NotImplementedError,
                    jax.errors.JaxRuntimeError):
                report.skipped.append(
                    f"{label}: memory_analysis unavailable on this backend")
                continue
            model = spec_state_bytes(spec, K, T)
            ratio = temp / max(model, 1)
            report.memory_ratios[(spec.method, K, T)] = ratio
            _expect(report, label, temp <= model * tol,
                    f"compiled temp {temp:,}B > model {model:,}B x "
                    f"tolerance {tol} — the planner would under-budget "
                    f"this spec")
            # sanity: the module parses under the roofline cost walker
            cost = analyze_text(compiled.as_text())
            _expect(report, f"{label} hlo-cost", cost.flops > 0,
                    "hlo_cost.analyze_text saw no flops in the compiled "
                    "module (parser drift?)")
    return report


# ---------------------------------------------------------------------------
# Streaming (stateful) contracts — tiny live run
# ---------------------------------------------------------------------------

def check_streaming_contracts(specs: Sequence[DecodeSpec] = STREAMING_SPECS,
                              K: int = 16, T: int = 48, seed: int = 0,
                              report: ContractReport | None = None
                              ) -> ContractReport:
    report = report if report is not None else ContractReport()
    rng = np.random.default_rng(seed)
    log_pi = jax.nn.log_softmax(
        jnp.asarray(rng.normal(size=(K,)), jnp.float32))
    log_A = jax.nn.log_softmax(
        jnp.asarray(rng.normal(size=(K, K)), jnp.float32), axis=1)
    em = jnp.asarray(rng.normal(size=(T, K)), jnp.float32)
    for spec in specs:
        label = f"streaming[{spec.method} K={K} T={T}]"
        dec = spec.make_streaming(log_pi, log_A)
        chunk = getattr(spec, "stream_chunk", 16)
        peak = 0
        for s in range(0, T, chunk):
            dec.feed(em[s:s + chunk])
            peak = max(peak, dec.live_state_bytes())
        dec.flush()
        path = dec.path
        _expect(report, f"{label} path",
                path.shape == (T,) and path.dtype == np.int32,
                f"got shape={path.shape} dtype={path.dtype}; want ({T},) "
                f"int32")
        model = spec_state_bytes(spec, K, T)
        _expect(report, f"{label} live-state",
                peak <= model,
                f"measured peak live state {peak:,}B exceeds the planner "
                f"model {model:,}B — decoder_state_bytes({spec.method!r}) "
                f"drifted from the implementation")
    return report


# ---------------------------------------------------------------------------
# Aggregate entry point
# ---------------------------------------------------------------------------

def check_contracts(quick: bool = False) -> ContractReport:
    """Run every contract family over every registered spec.

    ``quick`` shrinks the grids to one point each (pre-commit latency);
    the full grid is what CI and `make lint` run.
    """
    # keep the registry honest: every method must be covered by one family
    covered = ({s.method for s in TRACEABLE_SPECS}
               | {s.method for s in STREAMING_SPECS})
    report = ContractReport()
    missing = set(SPEC_BY_METHOD) - covered
    _expect(report, "registry coverage", not missing,
            f"methods {sorted(missing)} registered in SPEC_BY_METHOD but "
            f"not covered by the contract checker")
    shape_grid = SHAPE_GRID[:1] if quick else SHAPE_GRID
    batch_grid = BATCH_GRID[:1] if quick else BATCH_GRID
    mem_grid = MEMORY_GRID[:1] if quick else MEMORY_GRID
    check_shape_contracts(grid=shape_grid, batch_grid=batch_grid,
                          report=report)
    check_memory_contracts(grid=mem_grid, report=report)
    check_streaming_contracts(report=report)
    return report
