"""flashlint rules — the AST project linter behind ``python -m repro.analysis``.

Rule catalogue (see `RULES`):

  FL001  raw jax mesh/shard_map API (``jax.shard_map``,
         ``jax.experimental.shard_map``, ``jax.make_mesh``,
         ``jax.sharding.AbstractMesh``) anywhere except
         ``runtime/jaxcompat.py``.  Those surfaces drift across jax releases;
         PR 3 resurrected the whole distributed subsystem by funnelling them
         through the compat shim, and this rule keeps it that way.

  FL002  host-sync primitives inside the jit-reachable decode hot paths
         (``core/`` and ``kernels/``): ``.item()``, ``jax.device_get``,
         ``jax.block_until_ready``, ``np.asarray``/``np.array`` (device ->
         host copies), and ``float()``/``int()``/``bool()`` applied to an
         expression that mentions a traced value (a ``jnp.``/``jax.`` call
         chain, or a subscript of decoder state on ``self``).  Static shape
         metadata (``.shape``/``.ndim``/``.dtype``) is exempt.  Intentional
         syncs — the online decoders' commit points — carry a reasoned
         disable comment instead of being silent.

  FL003  ``sys.path`` manipulation (removed repo-wide in PR 4; this keeps it
         out).

  FL004  legacy string-dispatch ``viterbi_decode(method=...)`` anywhere
         except the pinned deprecation shim (``core/api.py``) and tests.
         New call sites must construct a typed `DecodeSpec`.

  FL005  malformed ``flashlint: disable`` comment (unknown rule code or
         missing reason) — a disable that does not say *why* suppresses
         nothing.

  FL006  raw Pallas API (``pl.pallas_call`` / ``pl.BlockSpec`` or any
         ``jax.experimental.pallas`` import) outside ``kernels/``.  The
         flashprove Pallas verifier (`analysis.pallas_check`) statically
         budgets VMEM for every kernel by enumerating the entry points in
         ``kernels/``; a pallas_call living anywhere else would silently
         escape that audit, so the kernel-layer boundary is enforced here.

  FL007  manual ``-inf`` masking — a ``jnp.where(...)`` whose arguments
         mention a neg-inf-like constant (``NEG_INF``, ``-jnp.inf``,
         ``float("-inf")``, a ``-1e8``-or-larger literal) — outside
         ``core/constraints.py`` and ``kernels/``.  Ad-hoc masks are where
         bit-identity dies: PR 10 centralised every allowed-set mask as an
         additive `ConstraintSpec` penalty so offline, batched, streaming
         and kernel paths apply *the same float adds*.  A hand-rolled
         ``where(mask, x, -inf)`` elsewhere silently forks that contract;
         either express it as a constraint or move it into the kernel layer
         (and if it is a genuine seam — sentinel padding, reduction
         identities — annotate it with a reasoned disable).

Suppression grammar, one or more comma-separated entries::

    x = float(delta[q])  # flashlint: disable=FL002(commit-point transfer)
    # flashlint: disable=FL002(applies to the next line)
    y = np.asarray(psi)
    # flashlint: disable-file=FL002(whole file is host-side numpy)

The reason inside ``(...)`` is mandatory.  ``disable-file`` may appear on any
standalone comment line and silences the rule for the entire file.
"""

from __future__ import annotations

import ast
import dataclasses
import io
import pathlib
import re
import tokenize
from typing import Iterable, Iterator

__all__ = ["RULES", "Violation", "lint_source", "lint_file", "lint_paths"]

RULES: dict[str, str] = {
    "FL001": "raw jax mesh/shard_map API outside runtime/jaxcompat.py",
    "FL002": "host-sync primitive in a jit-reachable decode hot path",
    "FL003": "sys.path manipulation",
    "FL004": "string-dispatch viterbi_decode outside the shim and tests",
    "FL005": "malformed flashlint disable comment",
    "FL006": "raw Pallas API outside kernels/",
    "FL007": "manual -inf masking outside core/constraints.py and kernels/",
}

# FL001 — exact dotted names that must stay inside the compat shim.
_FL001_DOTTED = {
    "jax.shard_map",
    "jax.make_mesh",
    "jax.sharding.AbstractMesh",
    "jax.experimental.shard_map",
    "jax.experimental.shard_map.shard_map",
}
_FL001_FROM = {
    ("jax", "shard_map"),
    ("jax", "make_mesh"),
    ("jax.sharding", "AbstractMesh"),
    ("jax.experimental.shard_map", "shard_map"),
}

# FL006 — the Pallas namespace and the two construction surfaces that define
# a kernel; any of these outside kernels/ bypasses the static VMEM audit.
_FL006_MODULE = "jax.experimental.pallas"
_FL006_ATTRS = {"pallas_call", "BlockSpec"}
_FL006_ROOTS = {"pl", "pallas", "pltpu"}

# FL002 — dotted call targets that always force a device->host sync, and
# attribute chains through these never refer to device data (static metadata).
_FL002_SYNC_CALLS = {
    "jax.device_get", "jax.block_until_ready",
    "np.asarray", "np.array", "numpy.asarray", "numpy.array",
}
_STATIC_ATTRS = {"shape", "ndim", "dtype", "weak_type", "sharding"}
_TRACED_ROOTS = {"jnp", "jax"}

# FL007 — names conventionally bound to the tropical -inf sentinel, and the
# magnitude at which a negative literal is clearly one (core.hmm.NEG_INF is
# -1.0e9; real log-probs never reach -1e8).
_FL007_NEG_NAMES = {"NEG_INF", "_SENTINEL", "_NEG", "_NEG_INF"}
_FL007_MAGNITUDE = 1e8

_DISABLE_ITEM = re.compile(r"(?P<code>[A-Z]{2}\d{3})\((?P<reason>[^()]*)\)")
_DISABLE_LINE = re.compile(
    r"#\s*flashlint:\s*(?P<kind>disable(?:-file)?)\s*=\s*(?P<body>\S.*)")


@dataclasses.dataclass(frozen=True)
class Violation:
    path: str
    line: int
    col: int
    code: str
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.code} {self.message}"


# ---------------------------------------------------------------------------
# Scope decisions (which rules apply to which files)
# ---------------------------------------------------------------------------

def _parts(path: str) -> tuple[str, ...]:
    return pathlib.PurePath(path).parts


def _is_jaxcompat(path: str) -> bool:
    return _parts(path)[-2:] == ("runtime", "jaxcompat.py")


def _is_hot_path(path: str) -> bool:
    """core/ and kernels/ — the jit-reachable decode stack (FL002 scope)."""
    parts = _parts(path)[:-1]
    return "core" in parts or "kernels" in parts


def _is_dispatch_shim(path: str) -> bool:
    return _parts(path)[-2:] == ("core", "api.py")


def _is_kernel_layer(path: str) -> bool:
    """kernels/ — the only home for raw Pallas API (FL006 scope)."""
    return "kernels" in _parts(path)[:-1]


def _is_constraints_file(path: str) -> bool:
    """core/constraints.py — the one blessed home for -inf penalty building."""
    return _parts(path)[-2:] == ("core", "constraints.py")


def _is_test_file(path: str) -> bool:
    parts = _parts(path)
    return ("tests" in parts[:-1] or parts[-1].startswith("test_")
            or parts[-1] == "conftest.py")


# ---------------------------------------------------------------------------
# Disable-comment parsing
# ---------------------------------------------------------------------------

def _parse_disables(src: str, path: str):
    """Returns (line -> {codes}, file-wide {codes}, FL005 violations).

    A disable on a code-bearing line covers that line; a disable on a
    standalone comment line covers the next line (for statements too long to
    carry the comment).  Only real COMMENT tokens count — strings and
    docstrings may mention the grammar without tripping FL005.
    """
    per_line: dict[int, set[str]] = {}
    file_wide: set[str] = set()
    bad: list[Violation] = []
    try:
        tokens = list(tokenize.generate_tokens(io.StringIO(src).readline))
    except (tokenize.TokenError, IndentationError, SyntaxError):
        return per_line, file_wide, bad   # ast.parse reports the real error
    for tok in tokens:
        if tok.type != tokenize.COMMENT:
            continue
        text, lineno = tok.string, tok.start[0]
        m = _DISABLE_LINE.search(text)
        if not m:
            if "flashlint" in text and "disable" in text:
                bad.append(Violation(path, lineno, 1, "FL005",
                                     "unparseable flashlint disable comment"))
            continue
        codes: set[str] = set()
        body = m.group("body")
        matched_spans = []
        for item in _DISABLE_ITEM.finditer(body):
            matched_spans.append(item.span())
            code, reason = item.group("code"), item.group("reason").strip()
            if code not in RULES:
                bad.append(Violation(path, lineno, 1, "FL005",
                                     f"unknown rule {code!r} in disable"))
            elif not reason:
                bad.append(Violation(
                    path, lineno, 1, "FL005",
                    f"disable of {code} has an empty reason; say why"))
            else:
                codes.add(code)
        leftover = _DISABLE_ITEM.sub("", body).strip().strip(",")
        if leftover and not leftover.startswith("#"):
            bad.append(Violation(
                path, lineno, 1, "FL005",
                f"malformed disable {leftover!r}; use CODE(reason)"))
        standalone = tok.line[:tok.start[1]].strip() == ""
        if m.group("kind") == "disable-file":
            file_wide |= codes
        elif standalone:
            per_line.setdefault(lineno + 1, set()).update(codes)
        else:
            per_line.setdefault(lineno, set()).update(codes)
    return per_line, file_wide, bad


# ---------------------------------------------------------------------------
# AST helpers
# ---------------------------------------------------------------------------

def _dotted(node: ast.AST) -> str | None:
    """'a.b.c' for an attribute chain rooted at a Name, else None."""
    names: list[str] = []
    while isinstance(node, ast.Attribute):
        names.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        names.append(node.id)
        return ".".join(reversed(names))
    return None


def _chain_root(node: ast.AST) -> str | None:
    """Root Name of an attribute/subscript/call chain, else None."""
    while True:
        if isinstance(node, ast.Attribute):
            node = node.value
        elif isinstance(node, ast.Subscript):
            node = node.value
        elif isinstance(node, ast.Call):
            node = node.func
        elif isinstance(node, ast.Name):
            return node.id
        else:
            return None


def _mentions_traced(node: ast.AST) -> bool:
    """Does this expression plausibly touch a traced/device value?

    True for jnp./jax.-rooted call chains and for subscripts of state held on
    ``self`` (the streaming decoders keep their live jax arrays there).
    Attribute chains through static metadata (.shape/.ndim/.dtype) are host
    Python and never count.
    """
    if isinstance(node, ast.Attribute):
        if node.attr in _STATIC_ATTRS:
            return False
        root = _chain_root(node)
        return root in _TRACED_ROOTS or _mentions_traced(node.value)
    if isinstance(node, ast.Subscript):
        return _mentions_traced(node.value) or _mentions_traced(node.slice)
    if isinstance(node, ast.Call):
        if any(_mentions_traced(a) for a in node.args):
            return True
        if any(_mentions_traced(k.value) for k in node.keywords):
            return True
        return _mentions_traced(node.func)
    if isinstance(node, ast.Name):
        return node.id == "self"
    if isinstance(node, ast.BinOp):
        return _mentions_traced(node.left) or _mentions_traced(node.right)
    if isinstance(node, ast.UnaryOp):
        return _mentions_traced(node.operand)
    if isinstance(node, (ast.Tuple, ast.List)):
        return any(_mentions_traced(e) for e in node.elts)
    return False


def _mentions_neg_inf(node: ast.AST) -> bool:
    """Does this expression contain a neg-inf-like constant anywhere?

    Matches the conventional sentinel names (`NEG_INF`, `_SENTINEL`, ...),
    ``.inf`` attributes (``jnp.inf`` / ``np.inf`` / ``math.inf``, usually
    under a unary minus), ``float("-inf")``, and negated numeric literals of
    ``-1e8`` magnitude or larger — recursing through arithmetic so scaled
    sentinels like ``4.0 * NEG_INF`` still register.
    """
    for sub in ast.walk(node):
        if isinstance(sub, ast.Name) and sub.id in _FL007_NEG_NAMES:
            return True
        if isinstance(sub, ast.Attribute) and sub.attr == "inf":
            return True
        if (isinstance(sub, ast.UnaryOp) and isinstance(sub.op, ast.USub)
                and isinstance(sub.operand, ast.Constant)
                and isinstance(sub.operand.value, (int, float))
                and abs(sub.operand.value) >= _FL007_MAGNITUDE):
            return True
        if (isinstance(sub, ast.Call) and isinstance(sub.func, ast.Name)
                and sub.func.id == "float" and len(sub.args) == 1
                and isinstance(sub.args[0], ast.Constant)
                and sub.args[0].value == "-inf"):
            return True
    return False


# ---------------------------------------------------------------------------
# The visitor
# ---------------------------------------------------------------------------

class _Visitor(ast.NodeVisitor):
    def __init__(self, path: str):
        self.path = path
        self.check_fl001 = not _is_jaxcompat(path)
        self.check_fl002 = _is_hot_path(path)
        self.check_fl004 = not (_is_dispatch_shim(path)
                                or _is_test_file(path))
        self.check_fl006 = not (_is_kernel_layer(path) or _is_test_file(path))
        self.check_fl007 = not (_is_constraints_file(path)
                                or _is_kernel_layer(path)
                                or _is_test_file(path))
        self.found: list[Violation] = []

    def _flag(self, node: ast.AST, code: str, message: str) -> None:
        self.found.append(Violation(self.path, getattr(node, "lineno", 1),
                                    getattr(node, "col_offset", 0) + 1,
                                    code, message))

    # -- imports (FL001) ----------------------------------------------------
    def visit_Import(self, node: ast.Import) -> None:
        if self.check_fl001:
            for alias in node.names:
                if alias.name in _FL001_DOTTED:
                    self._flag(node, "FL001",
                               f"import of {alias.name}; use "
                               f"repro.runtime.jaxcompat instead")
        if self.check_fl006:
            for alias in node.names:
                if (alias.name == _FL006_MODULE
                        or alias.name.startswith(_FL006_MODULE + ".")):
                    self._flag(node, "FL006",
                               f"import of {alias.name} outside kernels/; "
                               f"Pallas kernels live in repro.kernels where "
                               f"the VMEM audit can see them")
        self.generic_visit(node)

    def visit_ImportFrom(self, node: ast.ImportFrom) -> None:
        if self.check_fl001 and node.module:
            for alias in node.names:
                if (node.module, alias.name) in _FL001_FROM:
                    self._flag(node, "FL001",
                               f"'from {node.module} import {alias.name}'; "
                               f"use repro.runtime.jaxcompat instead")
        if self.check_fl006 and node.module:
            pallas_from = (node.module == "jax.experimental"
                           and any(a.name == "pallas" for a in node.names))
            if (pallas_from or node.module == _FL006_MODULE
                    or node.module.startswith(_FL006_MODULE + ".")):
                self._flag(node, "FL006",
                           f"'from {node.module} import ...' pulls Pallas "
                           f"API outside kernels/; move the kernel into "
                           f"repro.kernels")
        self.generic_visit(node)

    # -- attribute references (FL001, FL003) --------------------------------
    def visit_Attribute(self, node: ast.Attribute) -> None:
        dotted = _dotted(node)
        if dotted:
            if self.check_fl001 and dotted in _FL001_DOTTED:
                self._flag(node, "FL001",
                           f"raw {dotted}; use repro.runtime.jaxcompat "
                           f"instead")
            # exact match only: for `sys.path.insert(...)` the inner
            # `sys.path` Attribute node is visited too, so one flag suffices
            if dotted == "sys.path":
                self._flag(node, "FL003",
                           "sys.path manipulation; use PYTHONPATH=src or an "
                           "editable install")
            if self.check_fl006 and node.attr in _FL006_ATTRS:
                root = dotted.split(".", 1)[0]
                if root in _FL006_ROOTS or dotted.startswith(_FL006_MODULE):
                    self._flag(node, "FL006",
                               f"raw {dotted} outside kernels/; Pallas "
                               f"kernels live in repro.kernels where the "
                               f"VMEM audit can see them")
        self.generic_visit(node)

    # -- calls (FL002, FL004) -----------------------------------------------
    def visit_Call(self, node: ast.Call) -> None:
        func = node.func
        if self.check_fl002:
            if (isinstance(func, ast.Attribute) and func.attr == "item"
                    and not node.args and not node.keywords):
                self._flag(node, "FL002",
                           ".item() forces a device sync; keep scalars on "
                           "device or annotate the commit point")
            dotted = _dotted(func) if isinstance(func, ast.Attribute) else None
            if dotted in _FL002_SYNC_CALLS:
                self._flag(node, "FL002",
                           f"{dotted}() is a device->host transfer in a "
                           f"decode hot path")
            if (isinstance(func, ast.Name)
                    and func.id in ("float", "int", "bool")
                    and len(node.args) == 1
                    and _mentions_traced(node.args[0])):
                self._flag(node, "FL002",
                           f"{func.id}() on a traced value blocks on the "
                           f"device; batch the transfer or annotate it")
        if self.check_fl004:
            name = (func.id if isinstance(func, ast.Name)
                    else func.attr if isinstance(func, ast.Attribute)
                    else None)
            if name in ("viterbi_decode", "viterbi_decode_hmm"):
                self._flag(node, "FL004",
                           f"legacy {name}(method=...) dispatch; construct "
                           f"a typed DecodeSpec / ViterbiDecoder")
        if self.check_fl007 and isinstance(func, ast.Attribute):
            dotted = _dotted(func)
            if (dotted in ("jnp.where", "jax.numpy.where", "np.where",
                           "numpy.where")
                    and any(_mentions_neg_inf(a) for a in node.args)):
                self._flag(node, "FL007",
                           "manual -inf masking via where(); express the "
                           "allowed set as a core.constraints penalty (or "
                           "move it into kernels/) so every decode path "
                           "applies identical masking adds")
        self.generic_visit(node)


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------

def lint_source(src: str, path: str = "<string>") -> list[Violation]:
    """Lint one module's source text; `path` drives rule scoping."""
    per_line, file_wide, bad = _parse_disables(src, path)
    try:
        tree = ast.parse(src)
    except SyntaxError as e:
        return [Violation(path, e.lineno or 1, (e.offset or 0) + 1, "FL005",
                          f"syntax error: {e.msg}")]
    visitor = _Visitor(path)
    visitor.visit(tree)
    kept = [v for v in visitor.found
            if v.code not in file_wide
            and v.code not in per_line.get(v.line, ())]
    kept.extend(bad)
    kept.sort(key=lambda v: (v.line, v.col, v.code))
    return kept


def lint_file(path: str | pathlib.Path) -> list[Violation]:
    p = pathlib.Path(path)
    return lint_source(p.read_text(encoding="utf-8"), str(p))


def _iter_py(paths: Iterable[str | pathlib.Path]) -> Iterator[pathlib.Path]:
    for path in paths:
        p = pathlib.Path(path)
        if p.is_dir():
            yield from sorted(q for q in p.rglob("*.py")
                              if "__pycache__" not in q.parts)
        else:
            yield p


def lint_paths(paths: Iterable[str | pathlib.Path]
               ) -> tuple[list[Violation], int]:
    """Lint files/directories; returns (violations, files checked)."""
    violations: list[Violation] = []
    n_files = 0
    for p in _iter_py(paths):
        n_files += 1
        violations.extend(lint_file(p))
    return violations, n_files
