"""Recompilation detector for `ViterbiDecoder`'s spec-keyed jit caches.

`core/decoder.py` holds its jit callables in module-level tables keyed by the
spec itself — that is the whole point of specs being frozen and hashable.
Two failure modes silently destroy the design and show up only as latency:

  * a spec field stops participating in equality/hash (or a decoder grows a
    closure over per-instance state again), so two decoders built from equal
    specs stop sharing a compilation;
  * ragged `lengths` leak into a traced shape, so every new length mix inside
    one (B, T, K) bucket triggers a fresh compile.

This module turns both into hard failures.  `RetraceGuard` snapshots
`jit._cache_size()` for the callables behind a set of specs, runs the guarded
block, and raises `RetraceError` if the caches grew more than the declared
`allow_compiles`.  `check_retrace()` is the CLI battery: equal-spec reuse
across decoder instances, ragged-length reuse within a bucket, and a shape
change as the positive control (it *must* compile — a guard that never fires
guards nothing).
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import decoder as _decoder
from repro.core.decoder import ViterbiDecoder
from repro.core.spec import DecodeSpec, FlashSpec, FusedSpec, VanillaSpec

__all__ = ["RetraceError", "RetraceGuard", "check_retrace",
           "check_inflight_retrace", "supported"]


class RetraceError(AssertionError):
    """A jit cache compiled when the contract says it must not have."""


def _cache_size(fn) -> int | None:
    meth = getattr(fn, "_cache_size", None)
    if callable(meth):
        return int(meth())
    return None


def supported() -> bool:
    """Whether this jax exposes `jit._cache_size()` (0.4.x does)."""
    return _cache_size(_decoder._jit_decode(VanillaSpec())) is not None


class RetraceGuard:
    """Context manager: fail if the jit caches behind `specs` compile.

        with RetraceGuard([spec]):
            decoder_a.decode(em)
            decoder_b.decode(em2)      # equal spec, same shape: no compile

    `allow_compiles` declares an expected number of *new* cache entries
    (e.g. 1 when the guarded block intentionally introduces a new shape
    bucket); anything beyond that raises `RetraceError`.
    """

    def __init__(self, specs, *, allow_compiles: int = 0):
        self.specs = tuple(specs)
        self.allow_compiles = int(allow_compiles)
        self._before: dict[str, int] = {}

    def _sizes(self) -> dict[str, int]:
        sizes: dict[str, int] = {}
        for spec in self.specs:
            if spec.jittable:
                n = _cache_size(_decoder._jit_decode(spec))
                sizes[f"decode[{spec!r}]"] = -1 if n is None else n
            if spec.batch_method is not None:
                n = _cache_size(_decoder._jit_decode_batch(spec))
                sizes[f"decode_batch[{spec!r}]"] = -1 if n is None else n
        return sizes

    def __enter__(self) -> "RetraceGuard":
        self._before = self._sizes()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        if exc_type is not None:
            return False
        after = self._sizes()
        grown = {k: after[k] - self._before.get(k, 0)
                 for k in after
                 if after[k] >= 0 and after[k] > self._before.get(k, 0)}
        total = sum(grown.values())
        if total > self.allow_compiles:
            detail = ", ".join(f"{k}: +{v}" for k, v in sorted(grown.items()))
            raise RetraceError(
                f"{total} unexpected recompilation(s) "
                f"(allowed {self.allow_compiles}): {detail}")
        return False

    @property
    def compiles(self) -> dict[str, int]:
        """Cache growth observed so far (for the positive-control tests)."""
        return {k: v - self._before.get(k, 0)
                for k, v in self._sizes().items()
                if v >= 0 and v > self._before.get(k, 0)}


def _tiny_hmm(K: int, seed: int):
    rng = np.random.default_rng(seed)
    log_pi = jnp.asarray(rng.standard_normal(K), jnp.float32)
    log_A = jnp.asarray(rng.standard_normal((K, K)), jnp.float32)
    return log_pi, log_A


def check_retrace(specs: tuple[DecodeSpec, ...] = (VanillaSpec(),
                                                   FlashSpec(parallelism=4),
                                                   FusedSpec()),
                  K: int = 12, T: int = 24) -> list[str]:
    """Run the no-retrace battery; returns passed-scenario descriptions.

    Raises `RetraceError` on any unexpected compile.  Returns a single
    "skipped" note if this jax does not expose jit cache sizes.
    """
    if not supported():
        return ["skipped: jax.jit has no _cache_size() on this version"]
    passed: list[str] = []
    rng = np.random.default_rng(0)
    for spec in specs:
        log_pi, log_A = _tiny_hmm(K, seed=1)
        em = jnp.asarray(rng.standard_normal((T, K)), jnp.float32)
        dec = ViterbiDecoder(spec, log_pi, log_A)
        dec.decode(em)                       # warm the (K, T) bucket
        with RetraceGuard([spec]):
            dec.decode(em)                   # same decoder, same shape
            em2 = jnp.asarray(rng.standard_normal((T, K)), jnp.float32)
            dec.decode(em2)                  # same shape, new values
            log_pi2, log_A2 = _tiny_hmm(K, seed=2)
            dec2 = ViterbiDecoder(spec, log_pi2, log_A2)
            dec2.decode(em2)                 # equal spec, new instance + HMM
        passed.append(f"equal-spec no-retrace [{spec.method}]")

        if spec.batch_method is None:
            continue
        B = 3
        ems = jnp.asarray(rng.standard_normal((B, T, K)), jnp.float32)
        dec.decode_batch(ems, lengths=np.asarray([T, T // 3, T // 2]))
        with RetraceGuard([spec]):
            # new ragged mix inside the same (B, T, K) bucket
            dec.decode_batch(ems, lengths=np.asarray([2, T, T - 1]))
            dec2 = ViterbiDecoder(spec, *_tiny_hmm(K, seed=3))
            dec2.decode_batch(ems, lengths=np.asarray([T, 1, 5]))
        passed.append(f"ragged-bucket no-retrace [{spec.method}]")

    # positive control: a genuinely new shape bucket MUST compile, proving
    # the cache-size probe actually observes compilation.
    spec = specs[0]
    log_pi, log_A = _tiny_hmm(K, seed=1)
    dec = ViterbiDecoder(spec, log_pi, log_A)
    em_new = jnp.asarray(rng.standard_normal((T + 7, K)), jnp.float32)
    guard = RetraceGuard([spec], allow_compiles=1)
    with guard:
        dec.decode(em_new)
        if not guard.compiles:
            raise RetraceError(
                "positive control failed: a new (T, K) shape bucket did not "
                "register as a compile — the cache-size probe is broken")
    passed.append("positive control: new shape bucket compiles")
    return passed


def check_inflight_retrace(K: int = 12, block: int = 8,
                           slots: int = 3) -> list[str]:
    """Session churn on a live `InflightScheduler` must never recompile.

    The continuous-batching contract: the slot pool's jitted step has one
    fixed shape `(S, block, K)`, and sessions joining/leaving/forcing a
    flush only ever change array *contents*.  This battery warms a scheduler
    (including a forced flush, so the score-masking path is traced), then
    churns rounds of ragged joins/leaves — exact and bounded-lag mixed —
    under the cache-size probe.  A second scheduler with a different pool
    shape is the positive control.
    """
    if not supported():
        return ["skipped: jax.jit has no _cache_size() on this version"]
    from repro.serving.inflight import InflightScheduler, inflight_jit_fns

    rng = np.random.default_rng(0)
    log_pi, log_A = _tiny_hmm(K, seed=1)
    sched = InflightScheduler(log_pi, log_A, max_slots=slots, block=block)

    def em(T, scale=1.0):
        return (rng.standard_normal((T, K)) * scale).astype(np.float32)

    def churn_round(scale: float, lag: int | None) -> None:
        sids = [sched.submit(max_lag=(lag if i % 2 else None))
                for i in range(slots)]
        for i, sid in enumerate(sids):
            sched.feed(sid, em(2 * block + i, scale=scale))
            sched.pump()
        for sid in sids:
            sched.finish(sid)

    # warm-up: max_lag=1 on near-flat emissions all but guarantees forced
    # flushes, so _mask_slot is traced before the guard window opens
    churn_round(scale=0.01, lag=1)
    fns = inflight_jit_fns()
    if _cache_size(fns["mask_slot"]) == 0:
        raise RetraceError(
            "inflight warm-up never forced a flush; the battery would not "
            "cover the score-masking path")
    before = {k: _cache_size(f) for k, f in fns.items()}
    churn_round(scale=0.01, lag=1)
    churn_round(scale=1.0, lag=block)
    churn_round(scale=1.0, lag=None)
    after = {k: _cache_size(f) for k, f in fns.items()}
    grown = {k: after[k] - before[k] for k in after if after[k] > before[k]}
    if grown:
        detail = ", ".join(f"{k}: +{v}" for k, v in sorted(grown.items()))
        raise RetraceError(
            f"inflight session churn recompiled the slot-pool step: {detail}")
    passed = [f"inflight join/leave churn no-retrace "
              f"(S={slots}, block={block}, K={K})"]

    # positive control: a different pool shape MUST compile
    sched2 = InflightScheduler(log_pi, log_A, max_slots=slots + 1,
                               block=block)
    sid = sched2.submit()
    sched2.feed(sid, em(block + 1))
    sched2.pump()
    sched2.finish(sid)
    if _cache_size(fns["inflight_step"]) <= after["inflight_step"]:
        raise RetraceError(
            "positive control failed: a new (S, block, K) pool shape did "
            "not register as a compile — the cache-size probe is broken")
    passed.append("positive control: new pool shape compiles")
    return passed
