"""flashprove pass 1 — semantic analysis of traced decode jaxprs.

Where flashlint (PR 6) reads *source text* and the trace contracts check
*output avals*, this pass walks the **jaxpr itself** — the computation the
planner's choices actually compile — for every planner-reachable decode entry
point: each of the 10 registered `DecodeSpec`s (streaming specs via their
jitted chunk-advance surrogates), `ViterbiDecoder.decode` / `.decode_batch`
(the exact module-level jit wrappers `core/decoder.py` caches), over a
(K, T[, B]) grid.  `decode_sharded` is covered by `collective_check`.

Four things come out of each traced entry:

  * **PV101 — implicit dtype widening.**  Any `convert_element_type` whose
    target dtype is wider than its operand (same-kind widening, or anything
    promoting to a 64-bit type).  An accidental f64 upcast doubles every
    byte count the planner budgets with and silently halves throughput.

  * **PV102 — host callbacks.**  `pure_callback`/`io_callback`/debug
    callbacks inside jit-reachable decode code force host round-trips per
    call; the decode hot path must contain none.

  * **PV103 — oversized materialized intermediates.**  Any equation output
    (at any nesting depth) larger than ``max(PV103_MODEL_FACTOR x model,
    PV103_FLOOR_BYTES)`` — the signature of an accidental (K, K, T)
    broadcast that the cost model knows nothing about.

  * **DP-state bytes, retained bytes, flops.**  A liveness walk over the
    jaxpr derives two byte metrics — `dp_state_bytes` (peak *algorithm
    state*: loop carries, stacked scan outputs, Pallas output buffers, the
    paper's "live DP state") and `retained_bytes` (peak of *all* live
    cross-equation values, plumbing and transients included) — plus an
    analytic flop count.  `core/planner.py` cross-checks its formulas
    against the first (PV104 via `planner.crosscheck_state_bytes`):
    formula-vs-IR, where PR 6's contracts could only do formula-vs-allocator
    with 8-96x tolerances.

Everything here *traces* (`jax.make_jaxpr`); nothing executes a decode.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Iterator, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.spec import (DecodeSpec, OnlineBeamSpec, OnlineSpec,
                             SPEC_BY_METHOD)
from repro.core.planner import inflight_state_bytes, spec_state_bytes
from .findings import Finding, ProveReport

__all__ = [
    "IRStats", "JAXPR_GRID", "JAXPR_BATCH_GRID", "DEEP_GRID",
    "DEEP_BATCH_GRID", "INFLIGHT_GRID", "DEEP_INFLIGHT_GRID",
    "CONSTRAINED_GRID", "DEEP_CONSTRAINED_GRID",
    "PV103_MODEL_FACTOR", "PV103_FLOOR_BYTES",
    "entry_jaxpr", "batch_entry_jaxpr", "inflight_entry_jaxpr",
    "constrained_entry_jaxpr", "constrained_masked_entry_jaxpr",
    "analyze_jaxpr", "retained_bytes", "dp_state_bytes", "flop_count",
    "jaxpr_peak_temp_bytes", "jaxpr_flops", "check_jaxpr",
]

#: (K, T) grid every spec's single-sequence entry is traced over.
JAXPR_GRID: tuple[tuple[int, int], ...] = ((16, 32), (24, 64), (64, 256))
#: (K, T, B) grid for the batched entry of batchable specs.
JAXPR_BATCH_GRID: tuple[tuple[int, int, int], ...] = ((16, 32, 3), (24, 48, 4))
#: --deep adds a Pallas-active point (K % 128 == 0 takes the fused kernel
#: path instead of the XLA fallback) at serving-realistic sizes.
DEEP_GRID: tuple[tuple[int, int], ...] = JAXPR_GRID + ((128, 384),)
DEEP_BATCH_GRID: tuple[tuple[int, int, int], ...] = (
    JAXPR_BATCH_GRID + ((128, 256, 4),))
#: (S, block, K) grid for the inflight slot-pool step (`serving.inflight`);
#: --deep adds a Pallas-active point.
INFLIGHT_GRID: tuple[tuple[int, int, int], ...] = ((4, 8, 16), (8, 16, 24))
DEEP_INFLIGHT_GRID: tuple[tuple[int, int, int], ...] = (
    INFLIGHT_GRID + ((8, 16, 128),))
#: (K, T, width) grid for the constrained entry points (PR 10): the banded
#: sliding-window decode and the mask-fused decode; --deep adds a point where
#: the masked trace takes the Pallas kernel path (K % 128 == 0).
CONSTRAINED_GRID: tuple[tuple[int, int, int], ...] = ((24, 64, 3),
                                                      (64, 256, 8))
DEEP_CONSTRAINED_GRID: tuple[tuple[int, int, int], ...] = (
    CONSTRAINED_GRID + ((128, 384, 8),))

#: An intermediate bigger than model x factor (with an absolute floor so tiny
#: grids don't false-positive on padding) is PV103.
PV103_MODEL_FACTOR = 4.0
PV103_FLOOR_BYTES = 1 << 20

_CALLBACK_PRIMS = ("callback", "debug_print", "outside_call")


# ---------------------------------------------------------------------------
# jaxpr plumbing
# ---------------------------------------------------------------------------

def _inner_jaxprs(eqn) -> list:
    """Inner (Closed)Jaxprs of a higher-order equation, flattened."""
    inner = []
    for val in eqn.params.values():
        for x in (val if isinstance(val, (tuple, list)) else (val,)):
            if hasattr(x, "eqns"):                       # open Jaxpr
                inner.append(x)
            elif hasattr(x, "jaxpr") and hasattr(getattr(x, "jaxpr"), "eqns"):
                inner.append(x.jaxpr)                    # ClosedJaxpr
    return inner


def iter_eqns(jaxpr, *, into_pallas: bool = True) -> Iterator:
    """Yield every equation at every nesting depth (depth-first)."""
    for eqn in jaxpr.eqns:
        yield eqn
        if eqn.primitive.name == "pallas_call" and not into_pallas:
            continue
        for inner in _inner_jaxprs(eqn):
            yield from iter_eqns(inner, into_pallas=into_pallas)


def _aval_bytes(aval) -> int:
    shape = getattr(aval, "shape", None)
    dtype = getattr(aval, "dtype", None)
    if shape is None or dtype is None:
        return 0
    return math.prod(shape) * np.dtype(dtype).itemsize


def _is_var(v) -> bool:
    return hasattr(v, "aval") and not hasattr(v, "val")   # Var, not Literal


# ---------------------------------------------------------------------------
# Retained-state liveness
# ---------------------------------------------------------------------------

#: Loop/kernel primitives whose outputs are *algorithm state*: carries that
#: thread a DP recurrence, tables a scan stacks, buffers a kernel writes.
_STATEFUL_PRIMS = frozenset({"scan", "while", "pallas_call"})


def _liveness_peak(jaxpr, *, stateful_only: bool) -> int:
    """Shared liveness walk behind `retained_bytes` / `dp_state_bytes`.

    Peak over equation positions of live value bytes.  Excludes the jaxpr's
    own inputs and outputs (caller-owned — the same carve-out
    `memory_analysis().temp_size_in_bytes` makes).  Higher-order equations
    contribute one iteration's working set of their body (`scan`/`while`
    bodies never materialize across iterations; `pjit` inlines; `cond`
    takes the max branch); Pallas kernel bodies contribute nothing
    (VMEM-resident — `pallas_check` budgets those).

    With ``stateful_only`` the walk counts only values produced by
    `_STATEFUL_PRIMS` — loop carries, stacked scan outputs, kernel output
    buffers — i.e. the IR counterpart of the planner's "live DP state".
    Plumbing copies (reshapes, reversals, pads) and per-step compute
    transients are excluded; those belong to the allocator, which
    `contracts.py` bounds separately.
    """
    jaxpr = getattr(jaxpr, "jaxpr", jaxpr)                # accept ClosedJaxpr
    boundary = {id(v) for v in jaxpr.invars}
    boundary |= {id(v) for v in jaxpr.constvars}
    boundary |= {id(v) for v in jaxpr.outvars if _is_var(v)}

    last_use: dict[int, int] = {}
    for i, eqn in enumerate(jaxpr.eqns):
        for v in eqn.invars:
            if _is_var(v):
                last_use[id(v)] = i

    live = 0
    sizes: dict[int, int] = {}
    peak = 0
    for i, eqn in enumerate(jaxpr.eqns):
        name = eqn.primitive.name
        if name == "pallas_call":
            inner = 0
        else:
            bodies = _inner_jaxprs(eqn)
            inner_vals = [_liveness_peak(b, stateful_only=stateful_only)
                          for b in bodies]
            inner = (max(inner_vals) if name == "cond"
                     else sum(inner_vals)) if inner_vals else 0
        counted = not stateful_only or name in _STATEFUL_PRIMS
        out_bytes = sum(_aval_bytes(v.aval) for v in eqn.outvars
                        if id(v) not in boundary) if counted else 0
        peak = max(peak, live + inner + out_bytes)
        if counted:
            for v in eqn.outvars:
                vid = id(v)
                if vid in boundary or vid not in last_use:
                    continue                              # output or dead
                sizes[vid] = _aval_bytes(v.aval)
                live += sizes[vid]
        for v in eqn.invars:
            vid = id(v) if _is_var(v) else None
            if vid in sizes and last_use.get(vid) == i:
                live -= sizes.pop(vid)
    return peak


def retained_bytes(jaxpr) -> int:
    """Peak bytes of *all* retained cross-equation values — temporaries,
    plumbing copies, DP state alike.  The honest "how much does this trace
    hold at once" number (reported in stats and benchmark JSON)."""
    return _liveness_peak(jaxpr, stateful_only=False)


def dp_state_bytes(jaxpr) -> int:
    """Peak bytes of *algorithm state*: loop carries, stacked scan outputs,
    Pallas output buffers, over their live ranges.  This is the quantity
    `planner.decoder_state_bytes` claims to model, so it is what PV104
    cross-checks the formulas against."""
    return _liveness_peak(jaxpr, stateful_only=True)


# ---------------------------------------------------------------------------
# Flop counting
# ---------------------------------------------------------------------------

_EW_PRIMS = frozenset({
    "add", "sub", "mul", "div", "max", "min", "pow", "rem", "neg", "abs",
    "exp", "log", "log1p", "expm1", "tanh", "logistic", "sqrt", "rsqrt",
    "floor", "ceil", "round", "sign", "select_n", "clamp", "and", "or",
    "xor", "not", "eq", "ne", "lt", "le", "gt", "ge", "nextafter",
    "integer_pow", "square",
})
_REDUCE_PRIMS = frozenset({
    "reduce_max", "reduce_min", "reduce_sum", "reduce_prod", "argmax",
    "argmin", "reduce_and", "reduce_or", "cumsum", "cummax", "cummin",
})


def flop_count(jaxpr) -> int:
    """Analytic flop estimate for one execution of `jaxpr`.

    `scan` multiplies its body by the trip count; `while` counts one
    iteration (a documented lower bound — trip counts are data-dependent);
    `pallas_call` multiplies its kernel body by the grid size.
    """
    jaxpr = getattr(jaxpr, "jaxpr", jaxpr)
    total = 0
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name == "scan":
            total += flop_count(eqn.params["jaxpr"]) * int(eqn.params["length"])
        elif name == "while":
            total += (flop_count(eqn.params["body_jaxpr"])
                      + flop_count(eqn.params["cond_jaxpr"]))
        elif name == "cond":
            total += max((flop_count(b) for b in eqn.params["branches"]),
                         default=0)
        elif name == "pallas_call":
            grid = getattr(eqn.params.get("grid_mapping"), "grid", ()) or ()
            steps = math.prod(int(g) for g in grid) if grid else 1
            total += flop_count(eqn.params["jaxpr"]) * steps
        elif name == "dot_general":
            (contract, _), _ = (eqn.params["dimension_numbers"][0],
                                eqn.params["dimension_numbers"][1])
            lhs = eqn.invars[0].aval
            cdim = math.prod(lhs.shape[d] for d in contract) or 1
            out = math.prod(getattr(eqn.outvars[0].aval, "shape", ())) or 1
            total += 2 * out * cdim
        elif name in _EW_PRIMS:
            total += math.prod(getattr(eqn.outvars[0].aval, "shape", ())) or 1
        elif name in _REDUCE_PRIMS:
            total += math.prod(getattr(eqn.invars[0].aval, "shape", ())) or 1
        else:
            for inner in _inner_jaxprs(eqn):
                total += flop_count(inner)
    return total


# ---------------------------------------------------------------------------
# Per-equation findings
# ---------------------------------------------------------------------------

def _kind(d: np.dtype) -> str:
    # the ml_dtypes floats (bfloat16, float8_*) register as numpy kind 'V';
    # treat them as floats or bf16 -> f32 never reads as a widening.
    return "f" if d.kind == "V" and "float" in d.name else d.kind


def _is_widening(old, new) -> bool:
    o, n = np.dtype(old), np.dtype(new)
    if o == n:
        return False
    if _kind(o) == _kind(n) and n.itemsize > o.itemsize:
        return True        # f32 -> f64, i32 -> i64, bf16/f16 -> f32 ...
    return n.itemsize >= 8 and n.kind in "fiuc" and n.itemsize > o.itemsize


def _eqn_findings(closed, subject: str, threshold: int) -> list[Finding]:
    found: list[Finding] = []
    for eqn in iter_eqns(getattr(closed, "jaxpr", closed)):
        name = eqn.primitive.name
        if name == "convert_element_type":
            old = eqn.invars[0].aval.dtype
            new = eqn.params["new_dtype"]
            if _is_widening(old, new):
                found.append(Finding(
                    "PV101", subject,
                    f"convert_element_type {np.dtype(old).name} -> "
                    f"{np.dtype(new).name} widens the traced computation"))
        elif any(tag in name for tag in _CALLBACK_PRIMS):
            found.append(Finding(
                "PV102", subject,
                f"host callback primitive {name!r} in jit-reachable decode "
                f"code"))
        for v in eqn.outvars:
            b = _aval_bytes(getattr(v, "aval", None))
            if b > threshold:
                shape = tuple(v.aval.shape)
                found.append(Finding(
                    "PV103", subject,
                    f"{name} materializes {shape} "
                    f"{np.dtype(v.aval.dtype).name} = {b:,}B "
                    f"(> threshold {threshold:,}B) — the cost model knows "
                    f"nothing this large"))
    return found


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------

def _abstract_hmm(K: int, T: int):
    return (jax.ShapeDtypeStruct((K,), jnp.float32),
            jax.ShapeDtypeStruct((K, K), jnp.float32),
            jax.ShapeDtypeStruct((T, K), jnp.float32))


def entry_jaxpr(spec: DecodeSpec, K: int, T: int):
    """Closed jaxpr of the spec's single-sequence decode at (K, T).

    Jittable specs trace `ViterbiDecoder.decode`'s exact jit body
    (`core.decoder._run_spec`).  The streaming specs are stateful host
    loops, so their traced surrogate is the jitted chunk advance the loop
    drives — the only jit-reachable computation they own.
    """
    from repro.core.decoder import _run_spec
    pi, A, em = _abstract_hmm(K, T)
    if isinstance(spec, OnlineSpec):
        from repro.kernels.ops import viterbi_chunk_step
        C = min(spec.stream_chunk, T)
        chunk = jax.ShapeDtypeStruct((C, K), jnp.float32)
        delta = jax.ShapeDtypeStruct((K,), jnp.float32)
        return jax.make_jaxpr(
            lambda a, e, d: viterbi_chunk_step(a, e, d))(A, chunk, delta)
    if isinstance(spec, OnlineBeamSpec):
        from repro.core.online import _beam_chunk_scan
        B = min(spec.beam_width, K)
        kchunk = min(spec.kchunk, K)
        Kp = -(-K // kchunk) * kchunk
        C = min(spec.stream_chunk, T)
        Ap = jax.ShapeDtypeStruct((Kp, Kp), jnp.float32)
        chunk = jax.ShapeDtypeStruct((C, Kp), jnp.float32)
        sc = jax.ShapeDtypeStruct((B,), jnp.float32)
        st = jax.ShapeDtypeStruct((B,), jnp.int32)
        return jax.make_jaxpr(
            lambda a, e, s, q: _beam_chunk_scan(a, e, s, q, B, kchunk)
        )(Ap, chunk, sc, st)
    return jax.make_jaxpr(
        lambda p, a, e: _run_spec(spec, p, a, e))(pi, A, em)


def batch_entry_jaxpr(spec: DecodeSpec, K: int, T: int, B: int):
    """Closed jaxpr of `ViterbiDecoder.decode_batch`'s jit body at (K, T, B)."""
    from repro.core.decoder import _run_spec_batch
    pi, A, _ = _abstract_hmm(K, T)
    em = jax.ShapeDtypeStruct((B, T, K), jnp.float32)
    lengths = jax.ShapeDtypeStruct((B,), jnp.int32)
    return jax.make_jaxpr(
        lambda e, p, a, ln: _run_spec_batch(spec, e, p, a, ln)
    )(em, pi, A, lengths)


def inflight_entry_jaxpr(S: int, block: int, K: int):
    """Closed jaxpr of the inflight scheduler's batched slot step.

    This is the one computation `serving.inflight.InflightScheduler` runs
    per `step()` — fixed shapes (S, block, K) for the pool's lifetime, seed
    masking and the slot-masked block advance fused into a single trace.
    """
    from repro.serving.inflight import _inflight_step
    pi = jax.ShapeDtypeStruct((K,), jnp.float32)
    A = jax.ShapeDtypeStruct((K, K), jnp.float32)
    em0 = jax.ShapeDtypeStruct((S, K), jnp.float32)
    fresh = jax.ShapeDtypeStruct((S,), jnp.bool_)
    em = jax.ShapeDtypeStruct((S, block, K), jnp.float32)
    delta = jax.ShapeDtypeStruct((S, K), jnp.float32)
    nfeed = jax.ShapeDtypeStruct((S,), jnp.int32)
    return jax.make_jaxpr(
        lambda p, a, e0, f, e, d, n: _inflight_step(p, a, e0, f, e, d, n)
    )(pi, A, em0, fresh, em, delta, nfeed)


def constrained_entry_jaxpr(K: int, T: int, width: int):
    """Closed jaxpr of the banded sliding-window decode at (K, T, width).

    This is what `FusedSpec(constraint=band)` runs when the band covers the
    horizon — the path whose whole point is a smaller DP state, so its IR
    gets the same PV104 formula-vs-IR treatment as the dense methods,
    against `constraints.banded_state_bytes`.
    """
    from repro.kernels.ops import viterbi_decode_banded
    pi, A, em = _abstract_hmm(K, T)
    centers = jnp.arange(T, dtype=jnp.int32) % K
    return jax.make_jaxpr(
        lambda p, a, e: viterbi_decode_banded(p, a, e, centers, width=width)
    )(pi, A, em)


def constrained_masked_entry_jaxpr(K: int, T: int):
    """Closed jaxpr of the mask-fused decode (penalties as traced operands).

    The generic constrained fused path: a static (K, K) transition penalty
    and a streaming (T, K) step penalty fused into the DP adds
    (`kernels.ops.viterbi_decode_fused_masked`).
    """
    from repro.kernels.ops import viterbi_decode_fused_masked
    pi, A, em = _abstract_hmm(K, T)
    t_pen = jax.ShapeDtypeStruct((K, K), jnp.float32)
    s_pen = jax.ShapeDtypeStruct((T, K), jnp.float32)
    return jax.make_jaxpr(
        lambda p, a, e, tp, sp: viterbi_decode_fused_masked(
            p, a, e, t_pen=tp, s_pen=sp))(pi, A, em, t_pen, s_pen)


@dataclasses.dataclass(frozen=True)
class IRStats:
    """What one traced entry point derives from its jaxpr."""
    retained_bytes: int     # all live cross-equation values (honest peak)
    dp_state_bytes: int     # loop-carried / stacked / kernel-output state
    flops: int
    model_bytes: int        # planner.spec_state_bytes at the same (K, T)


def analyze_jaxpr(closed, subject: str, model_bytes: int
                  ) -> tuple[IRStats, list[Finding]]:
    """Stats + per-equation findings for one traced entry point."""
    threshold = int(max(PV103_MODEL_FACTOR * model_bytes, PV103_FLOOR_BYTES))
    findings = _eqn_findings(closed, subject, threshold)
    stats = IRStats(retained_bytes=retained_bytes(closed),
                    dp_state_bytes=dp_state_bytes(closed),
                    flops=flop_count(closed), model_bytes=model_bytes)
    return stats, findings


def jaxpr_peak_temp_bytes(spec: DecodeSpec, K: int, T: int) -> int:
    """IR-derived peak DP-state bytes for `spec` at (K, T) — the quantity
    `planner.decoder_state_bytes` must upper-bound (PV104)."""
    return dp_state_bytes(entry_jaxpr(spec, K, T))


def jaxpr_flops(spec: DecodeSpec, K: int, T: int) -> int:
    """IR-derived flop count for `spec` at (K, T)."""
    return flop_count(entry_jaxpr(spec, K, T))


# ---------------------------------------------------------------------------
# The pass
# ---------------------------------------------------------------------------

def check_jaxpr(quick: bool = False, deep: bool = False,
                specs: Sequence[DecodeSpec] | None = None,
                crosscheck: Callable | None = None) -> ProveReport:
    """Trace every planner-reachable decode entry point and analyze its IR.

    ``quick`` shrinks the grids to one point each; ``deep`` extends them
    with the Pallas-active (K = 128) points.  ``crosscheck`` defaults to
    `planner.crosscheck_state_bytes` (PV104 formula-vs-IR).
    """
    if crosscheck is None:
        from repro.core.planner import crosscheck_state_bytes
        crosscheck = crosscheck_state_bytes
    if specs is None:
        specs = tuple(cls() for cls in SPEC_BY_METHOD.values())
    grid = DEEP_GRID if deep else (JAXPR_GRID[:1] if quick else JAXPR_GRID)
    bgrid = (DEEP_BATCH_GRID if deep
             else (JAXPR_BATCH_GRID[:1] if quick else JAXPR_BATCH_GRID))
    report = ProveReport()
    for spec in specs:
        for K, T in grid:
            subject = f"jaxpr:{spec.method}[K={K},T={T}]"
            model = spec_state_bytes(spec, K, T)
            try:
                closed = entry_jaxpr(spec, K, T)
            except Exception as e:       # tracing itself must not fail
                report.findings.append(Finding(
                    "PV103", subject, f"trace error {e!r}"))
                continue
            stats, found = analyze_jaxpr(closed, subject, model)
            report.findings.extend(found)
            err = crosscheck(spec, K, T, stats.dp_state_bytes)
            if err:
                report.findings.append(Finding("PV104", subject, err))
            report.stats[subject] = {
                "retained_bytes": stats.retained_bytes,
                "dp_state_bytes": stats.dp_state_bytes,
                "flops": stats.flops,
                "model_bytes": stats.model_bytes,
            }
            report.checks.append(subject)
        if spec.batch_method is None:
            continue
        for K, T, B in bgrid:
            subject = f"jaxpr:{spec.method}:batch[K={K},T={T},B={B}]"
            model = spec_state_bytes(spec, K, T) * B
            try:
                closed = batch_entry_jaxpr(spec, K, T, B)
            except Exception as e:
                report.findings.append(Finding(
                    "PV103", subject, f"trace error {e!r}"))
                continue
            stats, found = analyze_jaxpr(closed, subject, model)
            report.findings.extend(found)
            err = crosscheck(spec, K, T, stats.dp_state_bytes, batch=B)
            if err:
                report.findings.append(Finding("PV104", subject, err))
            report.stats[subject] = {
                "retained_bytes": stats.retained_bytes,
                "dp_state_bytes": stats.dp_state_bytes,
                "flops": stats.flops,
                "model_bytes": stats.model_bytes,
            }
            report.checks.append(subject)

    # the inflight serving tier's slot-pool step — not a DecodeSpec, but it
    # is planner-reachable (admission budgets against
    # `planner.inflight_state_bytes`) and jit-resident for the scheduler's
    # whole lifetime, so it gets the same PV101/102/103 walk plus an inline
    # PV104: the pool formula must upper-bound the IR's DP state.
    igrid = (DEEP_INFLIGHT_GRID if deep
             else (INFLIGHT_GRID[:1] if quick else INFLIGHT_GRID))
    for S, block, K in igrid:
        subject = f"jaxpr:inflight[S={S},block={block},K={K}]"
        model = inflight_state_bytes(K, block, S)
        try:
            closed = inflight_entry_jaxpr(S, block, K)
        except Exception as e:
            report.findings.append(Finding(
                "PV103", subject, f"trace error {e!r}"))
            continue
        stats, found = analyze_jaxpr(closed, subject, model)
        report.findings.extend(found)
        slack = 8 * block * S + 256
        if stats.dp_state_bytes > model + slack:
            report.findings.append(Finding(
                "PV104", subject,
                f"planner.inflight_state_bytes(K={K}, block={block}, "
                f"slots={S}) = {model:,}B does not cover the IR's DP state "
                f"{stats.dp_state_bytes:,}B (+{slack:,}B slack) — the "
                f"admission budget would under-account live slot state"))
        report.stats[subject] = {
            "retained_bytes": stats.retained_bytes,
            "dp_state_bytes": stats.dp_state_bytes,
            "flops": stats.flops,
            "model_bytes": stats.model_bytes,
        }
        report.checks.append(subject)

    # the constrained decode entry points (PR 10): the banded sliding-window
    # decode (PV104 against `banded_state_bytes` — the claim that a covering
    # band shrinks DP state must hold in the IR, not just the formula) and
    # the mask-fused decode (penalties are traced operands; the fused model
    # plus mask bytes must cover its DP state).
    from repro.core.constraints import banded_state_bytes
    cgrid = (DEEP_CONSTRAINED_GRID if deep
             else (CONSTRAINED_GRID[:1] if quick else CONSTRAINED_GRID))
    for K, T, width in cgrid:
        for subject, entry, model in (
                (f"jaxpr:constrained[K={K},T={T},band={width}]",
                 lambda: constrained_entry_jaxpr(K, T, width),
                 banded_state_bytes(K, T, width)),
                (f"jaxpr:constrained:masked[K={K},T={T}]",
                 lambda: constrained_masked_entry_jaxpr(K, T),
                 spec_state_bytes(SPEC_BY_METHOD["fused"](), K, T)
                 + K * K * 4 + T * K * 4)):
            try:
                closed = entry()
            except Exception as e:
                report.findings.append(Finding(
                    "PV103", subject, f"trace error {e!r}"))
                continue
            stats, found = analyze_jaxpr(closed, subject, model)
            report.findings.extend(found)
            slack = 8 * T + 256
            if stats.dp_state_bytes > model + slack:
                report.findings.append(Finding(
                    "PV104", subject,
                    f"constrained-path model {model:,}B does not cover the "
                    f"IR's DP state {stats.dp_state_bytes:,}B "
                    f"(+{slack:,}B slack) — the banded/masked footprint "
                    f"claim the planner prices is wrong"))
            report.stats[subject] = {
                "retained_bytes": stats.retained_bytes,
                "dp_state_bytes": stats.dp_state_bytes,
                "flops": stats.flops,
                "model_bytes": model,
            }
            report.checks.append(subject)
    return report
