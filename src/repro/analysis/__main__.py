"""CLI for the analysis gate: ``python -m repro.analysis`` / ``make lint``.

Runs the two tiers in order — tier 1 flashlint (AST lint, trace-time
contracts, retrace battery) and tier 2 flashprove (jaxpr semantics, Pallas
VMEM/tiling, collective walk) — and exits non-zero if any layer fails.
Layer selection flags exist so pre-commit can run the sub-second lint alone
while CI runs everything; ``--deep`` is what the `analysis-deep` CI job
runs, with ``--report`` uploading the findings as a JSON artifact.
"""

from __future__ import annotations

import argparse
import pathlib
import sys


def _default_paths() -> list[pathlib.Path]:
    # the installed/in-tree `repro` package itself — `src/` when run from a
    # checkout, site-packages when run from an install; either way the gate
    # covers every module the decode stack ships.
    return [pathlib.Path(__file__).resolve().parent.parent]


def _run_lint(paths: list[pathlib.Path]) -> int:
    from .lint import lint_paths
    violations, n_files = lint_paths(paths)
    for v in violations:
        print(v)
    status = "clean" if not violations else f"{len(violations)} violation(s)"
    print(f"flashlint: {n_files} file(s) checked, {status}")
    return 1 if violations else 0


def _run_contracts(quick: bool) -> int:
    from .contracts import check_contracts
    report = check_contracts(quick=quick)
    for line in report.failures:
        print(f"CONTRACT FAIL: {line}")
    for line in report.skipped:
        print(f"contract skipped: {line}")
    if report.memory_ratios:
        worst = max(report.memory_ratios.items(), key=lambda kv: kv[1])
        (method, K, T), ratio = worst
        print(f"contracts: {len(report.checks)} check(s) passed, "
              f"{len(report.failures)} failed; worst compiled/model memory "
              f"ratio {ratio:.2f}x ({method}, K={K}, T={T})")
    else:
        print(f"contracts: {len(report.checks)} check(s) passed, "
              f"{len(report.failures)} failed")
    return 0 if report.ok else 1


def _run_retrace() -> int:
    from .retrace import RetraceError, check_inflight_retrace, check_retrace
    try:
        passed = check_retrace()
        passed += check_inflight_retrace()
    except RetraceError as e:
        print(f"RETRACE FAIL: {e}")
        return 1
    for line in passed:
        print(f"retrace: {line}")
    return 0


def _run_prove(quick: bool, deep: bool,
               report_path: pathlib.Path | None) -> int:
    from .prove import run_prove
    report = run_prove(quick=quick, deep=deep)
    for finding in report.findings:
        print(f"PROVE FAIL: {finding}")
    for finding, reason in report.waived:
        print(f"prove waived: {finding.code} {finding.subject} ({reason})")
    for line in report.skipped:
        print(f"prove skipped: {line}")
    tier = "deep" if deep else ("quick" if quick else "fast")
    print(f"flashprove[{tier}]: {len(report.checks)} entry point(s) "
          f"analyzed, {len(report.findings)} active finding(s), "
          f"{len(report.waived)} waived")
    if report_path is not None:
        report.dump(report_path)
        print(f"flashprove: findings report written to {report_path}")
    return 0 if report.ok else 1


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="analysis gate: flashlint (AST lint + contracts + "
                    "retrace guard) and flashprove (jaxpr semantics + "
                    "Pallas VMEM/tiling + collective walk)")
    ap.add_argument("paths", nargs="*", type=pathlib.Path,
                    help="files/directories to lint (default: the repro "
                         "package)")
    only = ap.add_mutually_exclusive_group()
    only.add_argument("--lint-only", action="store_true",
                      help="run just the AST linter (sub-second; what "
                           "pre-commit runs)")
    only.add_argument("--contracts-only", action="store_true",
                      help="run just the trace-time contract checker")
    only.add_argument("--retrace-only", action="store_true",
                      help="run just the recompilation battery")
    only.add_argument("--prove-only", action="store_true",
                      help="run just the flashprove semantic passes")
    ap.add_argument("--quick", action="store_true",
                    help="shrink the contract/prove grids to one point each")
    ap.add_argument("--deep", action="store_true",
                    help="full flashprove grids + the Pallas-active K=128 "
                         "jaxpr points + the VMEM ladder (the analysis-deep "
                         "CI job)")
    ap.add_argument("--report", type=pathlib.Path, metavar="PATH",
                    help="write the flashprove findings report as JSON")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalogue and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        from .findings import PROVE_RULES
        from .lint import RULES
        for code, summary in sorted({**RULES, **PROVE_RULES}.items()):
            print(f"{code}  {summary}")
        return 0

    run_all = not (args.lint_only or args.contracts_only
                   or args.retrace_only or args.prove_only)
    rc = 0
    if run_all or args.lint_only:
        rc |= _run_lint([p for p in (args.paths or _default_paths())])
    if run_all or args.contracts_only:
        rc |= _run_contracts(quick=args.quick)
    if run_all or args.retrace_only:
        rc |= _run_retrace()
    if run_all or args.prove_only:
        rc |= _run_prove(quick=args.quick, deep=args.deep,
                         report_path=args.report)
    return rc


if __name__ == "__main__":
    sys.exit(main())
