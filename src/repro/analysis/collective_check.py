"""flashprove pass 3 — no collectives in the data-parallel sharded decode.

`ViterbiDecoder.decode_sharded` shards the request bucket over a mesh axis
with the HMM tensors replicated; sequences are independent, so the shard
body must be *embarrassingly* data-parallel — zero cross-device traffic.
A collective sneaking in (a stray `psum` from a reduction written over the
batch axis, an `all_gather` from a sharding-rule fallback) would silently
serialize every decode step on device interconnect.

The check is structural, not behavioral: the sharded entry is traced over a
single-axis mesh for every batchable method and the jaxpr — including every
`shard_map` body — is walked for collective primitives (PV301).  Tracing is
mesh-size-independent (`psum` binds the same equation on a 1-device axis),
so the pass runs on the CPU lint host with no devices to spare.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .findings import Finding, ProveReport
from .jaxpr_check import iter_eqns

__all__ = ["COLLECTIVE_PRIMS", "collectives_in", "check_collectives"]

#: Cross-device primitives that must not appear in the shard body.  Matched
#: by exact name or prefix (``psum`` also catches ``psum2``/``psum_invariant``
#: across jax versions).
COLLECTIVE_PRIMS: tuple[str, ...] = (
    "psum", "pmax", "pmin", "pmean", "all_gather", "all_to_all",
    "reduce_scatter", "ppermute", "pbroadcast", "pgather", "pshuffle",
)


def _is_collective(prim_name: str) -> bool:
    return any(prim_name == c or prim_name.startswith(c + "_")
               or prim_name.startswith(c) and prim_name[len(c):].isdigit()
               for c in COLLECTIVE_PRIMS)


def collectives_in(closed) -> list[str]:
    """Names of collective primitives anywhere in a traced jaxpr."""
    return sorted({eqn.primitive.name
                   for eqn in iter_eqns(getattr(closed, "jaxpr", closed))
                   if _is_collective(eqn.primitive.name)})


def check_collectives(quick: bool = False, deep: bool = False) -> ProveReport:
    """Trace `decode_sharded` for every batchable spec; PV301 per collective.

    ``quick`` checks one method; ``deep`` currently equals the default run
    (the walk is already exhaustive over methods — the flag is accepted for
    CLI symmetry).
    """
    del deep
    from repro.core.decoder import ViterbiDecoder
    from repro.core.spec import SPEC_BY_METHOD
    from repro.runtime.jaxcompat import make_mesh

    mesh = make_mesh((1,), ("data",))
    K, T, B = 8, 16, 4
    log_pi = jnp.zeros((K,), jnp.float32)
    log_A = jnp.zeros((K, K), jnp.float32)
    ems = jnp.zeros((B, T, K), jnp.float32)
    lengths = jnp.full((B,), T, jnp.int32)

    report = ProveReport()
    specs = [cls() for cls in SPEC_BY_METHOD.values()
             if cls().batch_method is not None]
    if quick:
        specs = specs[:1]
    for spec in specs:
        subject = f"collective:{spec.method}"
        dec = ViterbiDecoder(spec, log_pi, log_A)
        try:
            closed = jax.make_jaxpr(
                lambda e, ln: dec.decode_sharded(e, ln, mesh=mesh)
            )(ems, lengths)
        except Exception as e:
            report.findings.append(Finding(
                "PV301", subject, f"trace error {e!r}"))
            continue
        found = collectives_in(closed)
        for name in found:
            report.findings.append(Finding(
                "PV301", subject,
                f"collective {name!r} in the sharded decode body; "
                f"data-parallel decode must not touch the interconnect"))
        report.stats[subject] = {"collectives": found}
        report.checks.append(subject)
    return report
