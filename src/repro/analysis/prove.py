"""flashprove — orchestration of the three semantic passes + waivers.

`run_prove` is the library entry the CLI, CI, and tests share: run the
jaxpr, Pallas, and collective passes, gather `FLASHPROVE_WAIVERS`
declarations from the decode stack, and split findings into active vs
waived.  Zero active findings is the merge bar (`report.ok`).

Tiers (mirrors the flashlint/contracts split):

  * default — jaxpr pass over the standard grid, one Pallas config per
    kernel, collective walk of one method.  Fast enough for `make lint`.
  * ``--quick`` — single grid point everywhere (pre-commit smoke).
  * ``--deep`` — full grids plus the Pallas-active K=128 jaxpr points and
    the VMEM ladder up to the runtime guard's edge; what CI's
    `analysis-deep` job runs and uploads as a JSON artifact.
"""

from __future__ import annotations

from .findings import ProveReport, apply_waivers, collect_waivers

__all__ = ["run_prove"]


def run_prove(quick: bool = False, deep: bool = False,
              vmem_budget: int | None = None) -> ProveReport:
    """Run all flashprove passes; returns a report with waivers applied."""
    from .collective_check import check_collectives
    from .jaxpr_check import check_jaxpr
    from .pallas_check import DEFAULT_VMEM_BUDGET, check_pallas

    report = ProveReport()
    report.extend(check_jaxpr(quick=quick, deep=deep))
    report.extend(check_pallas(quick=quick or not deep, deep=deep,
                               budget=vmem_budget or DEFAULT_VMEM_BUDGET))
    report.extend(check_collectives(quick=quick, deep=deep))

    waivers, malformed = collect_waivers()
    # Unused-waiver policy needs the full finding surface; partial runs
    # (quick / default) skip it so a narrowed grid can't flag a waiver
    # that only matches deep-tier subjects.
    active, waived = apply_waivers(report.findings, waivers,
                                   require_used=deep and not quick)
    report.findings = malformed + active
    report.waived.extend(waived)
    return report
