"""Static analysis that gates the decode stack — two tiers, one CLI
(``python -m repro.analysis``, ``make lint``).

**Tier 1 — flashlint** (source/trace level, PR 6):

  * `analysis.lint` — an AST project linter with repo-specific rules
    (FL001..FL006): raw jax mesh/shard_map API outside `runtime/jaxcompat`,
    host-sync primitives in the jit-reachable decode hot paths, `sys.path`
    manipulation, legacy string-dispatch `viterbi_decode` outside the pinned
    shim, and raw Pallas API outside `kernels/`.  Intentional exceptions are
    documented in place with ``# flashlint: disable=FL002(reason)`` comments.

  * `analysis.contracts` — a trace-time contract checker: every registered
    `DecodeSpec` is run under `jax.eval_shape` over a (K, T, B) grid (no
    execution) asserting output shapes/dtypes/weak-types, and the planner's
    `decoder_state_bytes` cost model is cross-checked against the compiled
    executables' `memory_analysis()` within pinned per-method tolerances.

  * `analysis.retrace` — a recompilation detector over `ViterbiDecoder`'s
    spec-keyed jit caches: repeated calls with an equal spec, or ragged
    lengths within one shape bucket, must never trigger a retrace.

**Tier 2 — flashprove** (IR level, this PR): semantic passes over *traced
computations* rather than source text.

  * `analysis.jaxpr_check` — traces every planner-reachable decode entry
    point and walks the jaxpr: dtype widenings (PV101), host callbacks
    (PV102), oversized materialized intermediates (PV103), and a liveness
    walk deriving DP-state/retained bytes + flops, cross-checked against
    `planner.decoder_state_bytes` formula-vs-IR (PV104).

  * `analysis.pallas_check` — reads every `pl.pallas_call`'s declared
    BlockSpecs back out of traced kernels and verifies (8, 128) tile
    alignment (PV201) and per-grid-step VMEM residency against the runtime
    budget for every reachable tile config (PV202).

  * `analysis.collective_check` — walks the sharded decode jaxpr and fails
    on any collective primitive (PV301); data-parallel decode must not
    touch the interconnect.

  Intentional exceptions are declared as module-level `FLASHPROVE_WAIVERS`
  in the module that owns the computation (`analysis.findings` has the
  grammar); `analysis.prove.run_prove` orchestrates passes + waivers.
"""

from __future__ import annotations

from .lint import RULES, Violation, lint_file, lint_paths, lint_source

__all__ = [
    "RULES", "Violation", "lint_source", "lint_file", "lint_paths",
    "ContractError", "ContractReport", "MEMORY_TOLERANCE",
    "check_contracts", "compiled_state_bytes",
    "RetraceError", "RetraceGuard", "check_retrace",
    "PROVE_RULES", "Finding", "ProveReport", "collect_waivers",
    "apply_waivers", "run_prove", "check_jaxpr", "check_pallas",
    "check_collectives", "jaxpr_peak_temp_bytes", "jaxpr_flops",
]

# Everything beyond the AST linter pulls in jax; load lazily (PEP 562) so
# the pre-commit path (`python -m repro.analysis --lint-only`) stays
# sub-second.
_LAZY = {
    "ContractError": "contracts", "ContractReport": "contracts",
    "MEMORY_TOLERANCE": "contracts", "check_contracts": "contracts",
    "compiled_state_bytes": "contracts",
    "RetraceError": "retrace", "RetraceGuard": "retrace",
    "check_retrace": "retrace",
    "PROVE_RULES": "findings", "Finding": "findings",
    "ProveReport": "findings", "collect_waivers": "findings",
    "apply_waivers": "findings",
    "run_prove": "prove",
    "check_jaxpr": "jaxpr_check", "jaxpr_peak_temp_bytes": "jaxpr_check",
    "jaxpr_flops": "jaxpr_check",
    "check_pallas": "pallas_check",
    "check_collectives": "collective_check",
}


def __getattr__(name: str):
    mod = _LAZY.get(name)
    if mod is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    import importlib
    return getattr(importlib.import_module(f".{mod}", __name__), name)
