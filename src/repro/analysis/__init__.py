"""flashlint — static analysis that gates the decode stack.

Three layers, one CLI (``python -m repro.analysis``, ``make lint``):

  * `analysis.lint` — an AST project linter with repo-specific rules
    (FL001..FL005): raw jax mesh/shard_map API outside `runtime/jaxcompat`,
    host-sync primitives in the jit-reachable decode hot paths, `sys.path`
    manipulation, and legacy string-dispatch `viterbi_decode` outside the
    pinned shim.  Intentional exceptions are documented in place with
    ``# flashlint: disable=FL002(reason)`` comments.

  * `analysis.contracts` — a trace-time contract checker: every registered
    `DecodeSpec` is run under `jax.eval_shape` over a (K, T, B) grid (no
    execution) asserting output shapes/dtypes/weak-types, and the planner's
    `decoder_state_bytes` cost model is cross-checked against the compiled
    executables' `memory_analysis()` within pinned per-method tolerances so
    the budget -> plan ladder can never silently underestimate footprint.

  * `analysis.retrace` — a recompilation detector over `ViterbiDecoder`'s
    spec-keyed jit caches: repeated calls with an equal spec, or ragged
    lengths within one shape bucket, must never trigger a retrace.
"""

from __future__ import annotations

from .lint import RULES, Violation, lint_file, lint_paths, lint_source

__all__ = [
    "RULES", "Violation", "lint_source", "lint_file", "lint_paths",
    "ContractError", "ContractReport", "MEMORY_TOLERANCE",
    "check_contracts", "compiled_state_bytes",
    "RetraceError", "RetraceGuard", "check_retrace",
]

# contracts/retrace pull in jax; load them lazily (PEP 562) so the AST-only
# pre-commit path (`python -m repro.analysis --lint-only`) stays sub-second.
_LAZY = {
    "ContractError": "contracts", "ContractReport": "contracts",
    "MEMORY_TOLERANCE": "contracts", "check_contracts": "contracts",
    "compiled_state_bytes": "contracts",
    "RetraceError": "retrace", "RetraceGuard": "retrace",
    "check_retrace": "retrace",
}


def __getattr__(name: str):
    mod = _LAZY.get(name)
    if mod is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    import importlib
    return getattr(importlib.import_module(f".{mod}", __name__), name)
