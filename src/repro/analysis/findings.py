"""flashprove findings: the structured result type + the waiver registry.

The semantic passes (`jaxpr_check`, `pallas_check`, `collective_check`)
analyze *traced computations* — jaxprs and Pallas kernel signatures — so an
intentional exception cannot be a source comment the way flashlint's
``# flashlint: disable=FL002(reason)`` is: the finding has no source line.
Instead the module that owns the computation declares a module-level

    FLASHPROVE_WAIVERS = {
        "PV201:beam_step": "beam blocks are (B,) <= 256 ...",
    }

mapping ``CODE`` or ``CODE:subject-prefix`` to a mandatory human reason.  A
waiver with an empty reason, an unknown code, or that matches nothing in the
current run is itself a finding (PV000) — mirroring flashlint's FL005 rule
that a suppression which does not say *why* (or suppresses nothing)
suppresses nothing.

Finding code catalogue (`PROVE_RULES`):

  PV000  malformed or unused flashprove waiver
  PV101  implicit dtype widening (`convert_element_type` to a wider dtype)
  PV102  host callback primitive in jit-reachable decode code
  PV103  materialized intermediate above the per-spec bytes threshold
  PV104  planner cost model below the jaxpr-derived retained-state bytes
  PV201  Pallas block shape off the (8, 128) tile grid
  PV202  Pallas per-grid-step VMEM residency over budget
  PV301  unexpected collective in the data-parallel sharded decode
"""

from __future__ import annotations

import dataclasses
import importlib
import json
from typing import Iterable, Sequence

__all__ = ["PROVE_RULES", "Finding", "ProveReport", "collect_waivers",
           "apply_waivers", "WAIVER_MODULES"]

PROVE_RULES: dict[str, str] = {
    "PV000": "malformed or unused flashprove waiver",
    "PV101": "implicit dtype widening in a traced decode computation",
    "PV102": "host callback primitive in jit-reachable decode code",
    "PV103": "materialized intermediate above the bytes threshold",
    "PV104": "planner cost model below jaxpr-derived retained-state bytes",
    "PV201": "Pallas block shape off the (8, 128) tile grid",
    "PV202": "Pallas per-grid-step VMEM residency over budget",
    "PV301": "unexpected collective in the data-parallel sharded decode",
}

#: Modules scanned for `FLASHPROVE_WAIVERS` declarations — the decode stack's
#: kernel and core layers (the owners of every analyzed computation).
WAIVER_MODULES: tuple[str, ...] = (
    "repro.kernels.viterbi_dp",
    "repro.kernels.ops",
    "repro.kernels.beam_stream",
    "repro.kernels.tropical",
    "repro.core.vanilla",
    "repro.core.flash",
    "repro.core.flash_bs",
    "repro.core.assoc",
    "repro.core.batch",
    "repro.core.online",
    "repro.core.planner",
)


@dataclasses.dataclass(frozen=True)
class Finding:
    """One flashprove finding: a rule code plus the subject it fired on.

    subject is a stable, hierarchical label ("pass:entry:detail", e.g.
    ``jaxpr:flash[K=64,T=256]``) so waivers can prefix-match it.
    """
    code: str
    subject: str
    detail: str

    def __str__(self) -> str:
        return f"{self.code} {self.subject}: {self.detail}"

    def to_json(self) -> dict:
        return {"code": self.code, "rule": PROVE_RULES.get(self.code, "?"),
                "subject": self.subject, "detail": self.detail}


@dataclasses.dataclass
class ProveReport:
    """Aggregated result of a flashprove run (what `--report` serializes)."""
    findings: list[Finding] = dataclasses.field(default_factory=list)
    waived: list[tuple[Finding, str]] = dataclasses.field(default_factory=list)
    checks: list[str] = dataclasses.field(default_factory=list)
    skipped: list[str] = dataclasses.field(default_factory=list)
    #: per-entry stats: subject -> {"retained_bytes": ..., "flops": ...,
    #: "model_bytes": ...} (jaxpr pass) or {"vmem_bytes": ...} (pallas pass).
    stats: dict[str, dict] = dataclasses.field(default_factory=dict)

    @property
    def ok(self) -> bool:
        return not self.findings

    def extend(self, other: "ProveReport") -> None:
        self.findings.extend(other.findings)
        self.waived.extend(other.waived)
        self.checks.extend(other.checks)
        self.skipped.extend(other.skipped)
        self.stats.update(other.stats)

    def to_json(self) -> dict:
        return {
            "ok": self.ok,
            "findings": [f.to_json() for f in self.findings],
            "waived": [{**f.to_json(), "reason": r} for f, r in self.waived],
            "checks": len(self.checks),
            "skipped": self.skipped,
            "stats": self.stats,
        }

    def dump(self, path) -> None:
        with open(path, "w", encoding="utf-8") as fh:
            json.dump(self.to_json(), fh, indent=2, sort_keys=True)
            fh.write("\n")


def collect_waivers(modules: Sequence[str] = WAIVER_MODULES
                    ) -> tuple[dict[str, str], list[Finding]]:
    """Gather `FLASHPROVE_WAIVERS` declarations from the decode stack.

    Returns (waivers, malformed): waivers maps "CODE[:subject-prefix]" to its
    reason; malformed holds PV000 findings for empty reasons / unknown codes.
    """
    waivers: dict[str, str] = {}
    malformed: list[Finding] = []
    for name in modules:
        try:
            mod = importlib.import_module(name)
        except ImportError as e:
            malformed.append(Finding("PV000", f"waivers:{name}",
                                     f"module failed to import: {e!r}"))
            continue
        declared = getattr(mod, "FLASHPROVE_WAIVERS", None)
        if declared is None:
            continue
        if not isinstance(declared, dict):
            malformed.append(Finding(
                "PV000", f"waivers:{name}",
                "FLASHPROVE_WAIVERS must be a dict of "
                "'CODE[:subject-prefix]' -> reason"))
            continue
        for key, reason in declared.items():
            code = str(key).split(":", 1)[0]
            if code not in PROVE_RULES or code == "PV000":
                malformed.append(Finding(
                    "PV000", f"waivers:{name}",
                    f"unknown rule {code!r} in waiver {key!r}"))
                continue
            if not str(reason).strip():
                malformed.append(Finding(
                    "PV000", f"waivers:{name}",
                    f"waiver {key!r} has an empty reason; say why"))
                continue
            waivers[str(key)] = str(reason)
    return waivers, malformed


def _waiver_matches(waiver_key: str, finding: Finding) -> bool:
    code, _, prefix = waiver_key.partition(":")
    if code != finding.code:
        return False
    return not prefix or finding.subject.startswith(prefix)


def apply_waivers(findings: Iterable[Finding], waivers: dict[str, str],
                  *, require_used: bool = True
                  ) -> tuple[list[Finding], list[tuple[Finding, str]]]:
    """Split findings into (active, waived) per the waiver registry.

    A declared waiver that matched nothing becomes a PV000 active finding
    when ``require_used`` — stale waivers rot into blanket suppressions
    otherwise (only meaningful when `findings` came from a full run).
    """
    active: list[Finding] = []
    waived: list[tuple[Finding, str]] = []
    used: set[str] = set()
    for f in findings:
        hit = next((k for k in waivers if _waiver_matches(k, f)), None)
        if hit is None:
            active.append(f)
        else:
            used.add(hit)
            waived.append((f, waivers[hit]))
    if require_used:
        for key in sorted(set(waivers) - used):
            active.append(Finding(
                "PV000", f"waivers:{key}",
                "waiver matched no finding in this run; remove it or fix "
                "the subject prefix"))
    return active, waived
