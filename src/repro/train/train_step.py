"""Train-step factory: loss -> grads (with optional microbatch accumulation and
int8 error-feedback accumulation buffers) -> AdamW/ZeRO-1 update.

`make_train_step(model, ocfg)` returns a pure function
    train_step(state, batch) -> (state, metrics)
suitable for `jax.jit(..., donate_argnums=0)` under any mesh; sharding is
supplied at jit time from model.param_specs / optim.opt_state_specs /
configs.input_specs so the same step serves the smoke tests, the end-to-end
example and the 512-chip dry-run.

Grad accumulation uses `lax.scan` over microbatches: XLA's latency-hiding
scheduler overlaps microbatch i+1's compute with the tail collectives of
microbatch i, and the final (reduce-scattered) update touches each ZeRO shard
once.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.optim import adamw, compression


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: adamw.AdamWConfig = adamw.AdamWConfig()
    accum_steps: int = 1
    compress_accum: bool = False     # int8 + error-feedback accumulation


def init_train_state(model, key):
    params = model.init(key)
    return {"params": params, "opt": adamw.init_state(params)}


def abstract_train_state(model):
    params = model.abstract_params()
    f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    return {"params": params,
            "opt": {"m": jax.tree_util.tree_map(f32, params),
                    "v": jax.tree_util.tree_map(f32, params),
                    "step": jax.ShapeDtypeStruct((), jnp.int32)}}


def train_state_specs(model, rules, data_size: int):
    pspecs = model.param_specs(rules)
    shapes = model.abstract_params()
    data_axes = rules.axis("batch")
    if data_axes is None:
        data_axes = ("data",)
    if isinstance(data_axes, str):
        data_axes = (data_axes,)
    return {"params": pspecs,
            "opt": adamw.opt_state_specs(pspecs, shapes, data_axes, data_size)}


def make_train_step(model, tcfg: TrainConfig):
    def loss_fn(params, batch):
        return model.loss(params, batch)

    grad_fn = jax.value_and_grad(loss_fn)

    def single(state, batch):
        loss, grads = grad_fn(state["params"], batch)
        return loss, grads

    def accumulated(state, batch):
        """batch leaves have leading dim accum_steps * microbatch."""
        A = tcfg.accum_steps
        micro = jax.tree_util.tree_map(
            lambda x: x.reshape(A, x.shape[0] // A, *x.shape[1:]), batch)

        if not tcfg.compress_accum:
            def body(acc, mb):
                loss, grads = grad_fn(state["params"], mb)
                return jax.tree_util.tree_map(jnp.add, acc,
                                              {"l": loss, "g": grads}), None
            zero = {"l": jnp.float32(0),
                    "g": jax.tree_util.tree_map(
                        lambda p: jnp.zeros(p.shape, jnp.float32),
                        state["params"])}
            acc, _ = jax.lax.scan(body, zero, micro)
            return acc["l"] / A, jax.tree_util.tree_map(lambda g: g / A, acc["g"])

        # int8 error-feedback accumulation
        def body(carry, mb):
            ef, lsum = carry
            loss, grads = grad_fn(state["params"], mb)
            out = jax.tree_util.tree_map(
                compression.ef_accumulate, ef["q"], ef["scale"],
                ef["residual"], grads,
                is_leaf=lambda x: not isinstance(x, dict))
            new_ef = {
                "q": jax.tree_util.tree_map(
                    lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple)),
                "scale": jax.tree_util.tree_map(
                    lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple)),
                "residual": jax.tree_util.tree_map(
                    lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple)),
            }
            return (new_ef, lsum + loss), None

        ef0 = compression.init_ef_state(state["params"])
        (ef, lsum), _ = jax.lax.scan(body, (ef0, jnp.float32(0)), micro)
        grads = jax.tree_util.tree_map(
            lambda q, s: compression.dequantize(q, s) / A, ef["q"], ef["scale"])
        return lsum / A, grads

    def train_step(state, batch):
        if tcfg.accum_steps > 1:
            loss, grads = accumulated(state, batch)
        else:
            loss, grads = single(state, batch)
        new_params, new_opt, metrics = adamw.update(
            tcfg.opt, grads, state["opt"], state["params"])
        metrics = {"loss": loss, **metrics}
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


__all__ = ["TrainConfig", "init_train_state", "abstract_train_state",
           "train_state_specs", "make_train_step"]
