"""Training loop substrate."""

from .train_step import (TrainConfig, init_train_state, abstract_train_state,
                         train_state_specs, make_train_step)

__all__ = ["TrainConfig", "init_train_state", "abstract_train_state",
           "train_state_specs", "make_train_step"]
