"""Fused Viterbi forward-pass Pallas TPU kernel.

Runs the whole DP recursion
    delta_t[j] = max_k (delta_{t-1}[k] + log_A[k, j]) + em[t, j]
inside one kernel: the transition matrix stays resident in VMEM for the entire
sequence, emissions stream in (bt, K) blocks through the Pallas pipeline (which
double-buffers them — the paper's DDR->BRAM double-buffering scheme realised as
HBM->VMEM), backpointers stream out, and delta is carried across sequential grid
steps in a VMEM scratch.  Compared with the XLA `lax.scan` lowering this removes
the per-step HBM round-trip of delta (2*K*4 B/step) and the per-step kernel
launch — the DP becomes emission-streaming-bound, its roofline floor.

Constraints (checked in `ops.viterbi_forward`):
  * K multiple of 128 (lane width), K^2 * 4 B + working set within VMEM
    (K <= 1024 fp32 with default bt; larger K falls back to the XLA path).
  * TPU grid iteration is sequential ("arbitrary" dimension semantics), which is
    what makes the scratch carry legal.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax<0.5 exposes this dataclass as TPUCompilerParams
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def _viterbi_fwd_kernel(a_ref, em_ref, d0_ref, psi_ref, dT_ref, dscr, *,
                        bt: int, nsteps: int):
    ti = pl.program_id(0)

    @pl.when(ti == 0)
    def _seed():
        dscr[0, :] = d0_ref[...]

    log_a = a_ref[...]                       # (K, K), resident
    delta = dscr[0, :]                       # (K,)

    def body(s, delta):
        scores = delta[:, None] + log_a      # (K_src, K_dst)
        psi_ref[s, :] = jnp.argmax(scores, axis=0).astype(jnp.int32)
        return jnp.max(scores, axis=0) + em_ref[s, :]

    delta = jax.lax.fori_loop(0, bt, body, delta)
    dscr[0, :] = delta

    @pl.when(ti == nsteps - 1)
    def _emit():
        dT_ref[...] = delta


@functools.partial(jax.jit, static_argnames=("bt", "interpret"))
def viterbi_forward(log_A: jax.Array, em: jax.Array, delta0: jax.Array, *,
                    bt: int = 8, interpret: bool = False):
    """Fused forward pass.

    Args:
      log_A:  (K, K) transition log-probs.
      em:     (T, K) emission scores for steps 1..T (step 0 is in `delta0`).
      delta0: (K,) initial DP state.

    Returns:
      (psi, delta_T): (T, K) int32 backpointers and final (K,) DP state.
    """
    T, K = em.shape
    assert T % bt == 0, (T, bt)
    nsteps = T // bt

    return pl.pallas_call(
        functools.partial(_viterbi_fwd_kernel, bt=bt, nsteps=nsteps),
        grid=(nsteps,),
        in_specs=[
            pl.BlockSpec((K, K), lambda ti: (0, 0)),   # resident all steps
            pl.BlockSpec((bt, K), lambda ti: (ti, 0)),  # streamed
            pl.BlockSpec((K,), lambda ti: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bt, K), lambda ti: (ti, 0)),  # streamed out
            pl.BlockSpec((K,), lambda ti: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T, K), jnp.int32),
            jax.ShapeDtypeStruct((K,), em.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((1, K), em.dtype)],
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(log_A, em, delta0)


__all__ = ["viterbi_forward"]
