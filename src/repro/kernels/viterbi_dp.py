"""Fused Viterbi forward-pass Pallas TPU kernel (single-sequence and batched).

Runs the whole DP recursion
    delta_t[j] = max_k (delta_{t-1}[k] + log_A[k, j]) + em[t, j]
inside one kernel: the transition matrix stays resident in VMEM for the entire
launch, emissions stream in (bt, K) blocks through the Pallas pipeline (which
double-buffers them — the paper's DDR->BRAM double-buffering scheme realised as
HBM->VMEM), backpointers stream out, and delta is carried across sequential grid
steps in a VMEM scratch.  Compared with the XLA `lax.scan` lowering this removes
the per-step HBM round-trip of delta (2*K*4 B/step) and the per-step kernel
launch — the DP becomes emission-streaming-bound, its roofline floor.

The grid is (B, T // bt): the batch axis is the outer (slowest) grid dimension,
so one launch decodes a whole request bucket with `log_A` loaded exactly once.
The delta scratch is re-seeded from `delta0[b]` at each sequence's first block,
which is what makes the cross-block carry legal per sequence.

Ragged batches are handled by a per-step pad mask streamed alongside the
emissions: a masked step is a *tropical identity* — delta is left unchanged and
the emitted backpointer row is the identity permutation — so scores and
backtracked paths are bit-identical to decoding each sequence at its true
length.  The same mask lets odd T pad up to a bt multiple instead of degrading
the block size.

Constraints (checked in `ops.viterbi_forward[_batch]`):
  * K multiple of 128 (lane width), K^2 * 4 B + working set within VMEM
    (K <= 1024 fp32 with default bt; larger K falls back to the XLA path).
  * TPU grid iteration is sequential ("arbitrary" dimension semantics), which is
    what makes the scratch carry legal.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax<0.5 exposes this dataclass as TPUCompilerParams
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def _viterbi_fwd_kernel(a_ref, em_ref, pad_ref, d0_ref, psi_ref, dT_ref, dscr,
                        *, bt: int, nsteps: int):
    ti = pl.program_id(1)                    # time-block index (b is axis 0)

    @pl.when(ti == 0)
    def _seed():                             # new sequence: re-seed the carry
        dscr[0, :] = d0_ref[0, :]

    log_a = a_ref[...]                       # (K, K), resident
    K = log_a.shape[0]
    eye = jax.lax.broadcasted_iota(jnp.int32, (1, K), 1)[0]

    def body(s, delta):
        scores = delta[:, None] + log_a      # (K_src, K_dst)
        psi = jnp.argmax(scores, axis=0).astype(jnp.int32)
        new = jnp.max(scores, axis=0) + em_ref[0, s, :]
        is_pad = pad_ref[0, s] > 0.5         # tropical-identity step
        psi_ref[0, s, :] = jnp.where(is_pad, eye, psi)
        return jnp.where(is_pad, delta, new)

    delta = jax.lax.fori_loop(0, bt, body, dscr[0, :])
    dscr[0, :] = delta

    @pl.when(ti == nsteps - 1)
    def _emit():
        dT_ref[0, :] = delta


@functools.partial(jax.jit, static_argnames=("bt", "interpret"))
def viterbi_forward_batch(log_A: jax.Array, em: jax.Array, delta0: jax.Array,
                          pad: jax.Array | None = None, *,
                          bt: int = 8, interpret: bool = False):
    """Batched fused forward pass.

    Args:
      log_A:  (K, K) transition log-probs, shared across the batch.
      em:     (B, T, K) emission scores for steps 1..T (step 0 is in `delta0`).
      delta0: (B, K) initial DP states.
      pad:    optional (B, T); entries > 0.5 mark tropical-identity steps
              (delta frozen, identity backpointers).  None means no padding.

    Returns:
      (psi, delta_T): (B, T, K) int32 backpointers and final (B, K) DP states.
    """
    B, T, K = em.shape
    assert T % bt == 0, (T, bt)
    nsteps = T // bt
    if pad is None:
        pad = jnp.zeros((B, T), em.dtype)
    pad = pad.astype(em.dtype)

    return pl.pallas_call(
        functools.partial(_viterbi_fwd_kernel, bt=bt, nsteps=nsteps),
        grid=(B, nsteps),
        in_specs=[
            pl.BlockSpec((K, K), lambda b, ti: (0, 0)),      # resident
            pl.BlockSpec((1, bt, K), lambda b, ti: (b, ti, 0)),  # streamed
            pl.BlockSpec((1, bt), lambda b, ti: (b, ti)),
            pl.BlockSpec((1, K), lambda b, ti: (b, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bt, K), lambda b, ti: (b, ti, 0)),  # streamed out
            pl.BlockSpec((1, K), lambda b, ti: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, T, K), jnp.int32),
            jax.ShapeDtypeStruct((B, K), em.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((1, K), em.dtype)],
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary")),
        interpret=interpret,
    )(log_A, em, pad, delta0)


def _viterbi_fwd_masked_kernel(*refs, bt: int, nsteps: int, has_tmask: bool,
                               has_smask: bool):
    """Constraint-masked variant of `_viterbi_fwd_kernel`.

    The masks arrive as additive f32 penalties ({0, NEG_INF}, see
    `core.constraints`): the static transition penalty rides VMEM-resident
    next to `log_A` and is added once per grid step, the per-step state
    penalty streams in (bt, K) blocks alongside the emissions (shared across
    the batch — one schedule per constraint).  Both adds reproduce the
    reference `log_A + t_pen` / `em + s_pen` elementwise adds exactly, so
    the masked kernel is bit-identical to decoding pre-masked inputs.
    """
    it = iter(refs)
    a_ref = next(it)
    tm_ref = next(it) if has_tmask else None
    em_ref = next(it)
    sm_ref = next(it) if has_smask else None
    pad_ref = next(it)
    d0_ref = next(it)
    psi_ref = next(it)
    dT_ref = next(it)
    dscr = next(it)

    ti = pl.program_id(1)

    @pl.when(ti == 0)
    def _seed():
        dscr[0, :] = d0_ref[0, :]

    log_a = a_ref[...]
    if has_tmask:
        log_a = log_a + tm_ref[...]
    K = log_a.shape[0]
    eye = jax.lax.broadcasted_iota(jnp.int32, (1, K), 1)[0]

    def body(s, delta):
        scores = delta[:, None] + log_a
        psi = jnp.argmax(scores, axis=0).astype(jnp.int32)
        em_t = em_ref[0, s, :]
        if has_smask:
            em_t = em_t + sm_ref[s, :]
        new = jnp.max(scores, axis=0) + em_t
        is_pad = pad_ref[0, s] > 0.5
        psi_ref[0, s, :] = jnp.where(is_pad, eye, psi)
        return jnp.where(is_pad, delta, new)

    delta = jax.lax.fori_loop(0, bt, body, dscr[0, :])
    dscr[0, :] = delta

    @pl.when(ti == nsteps - 1)
    def _emit():
        dT_ref[0, :] = delta


@functools.partial(jax.jit, static_argnames=("bt", "interpret"))
def viterbi_forward_batch_masked(log_A: jax.Array, em: jax.Array,
                                 delta0: jax.Array,
                                 pad: jax.Array | None = None,
                                 tmask: jax.Array | None = None,
                                 smask: jax.Array | None = None, *,
                                 bt: int = 8, interpret: bool = False):
    """Batched fused forward pass with fused constraint penalties.

    Args:
      log_A, em, delta0, pad: as in `viterbi_forward_batch`.
      tmask: optional (K, K) f32 additive transition penalty (VMEM-resident).
      smask: optional (T, K) f32 additive per-step state penalty, shared
             across the batch, streamed in (bt, K) blocks with the emissions.
             Row t masks em[:, t] (the caller aligns step offsets).

    Returns:
      (psi, delta_T): (B, T, K) int32 backpointers and final (B, K) states.
    """
    B, T, K = em.shape
    assert T % bt == 0, (T, bt)
    nsteps = T // bt
    if pad is None:
        pad = jnp.zeros((B, T), em.dtype)
    pad = pad.astype(em.dtype)
    has_tmask = tmask is not None
    has_smask = smask is not None

    inputs = [log_A]
    in_specs = [pl.BlockSpec((K, K), lambda b, ti: (0, 0))]
    if has_tmask:
        inputs.append(tmask)
        in_specs.append(pl.BlockSpec((K, K), lambda b, ti: (0, 0)))
    inputs.append(em)
    in_specs.append(pl.BlockSpec((1, bt, K), lambda b, ti: (b, ti, 0)))
    if has_smask:
        inputs.append(smask)
        in_specs.append(pl.BlockSpec((bt, K), lambda b, ti: (ti, 0)))
    inputs += [pad, delta0]
    in_specs += [pl.BlockSpec((1, bt), lambda b, ti: (b, ti)),
                 pl.BlockSpec((1, K), lambda b, ti: (b, 0))]

    return pl.pallas_call(
        functools.partial(_viterbi_fwd_masked_kernel, bt=bt, nsteps=nsteps,
                          has_tmask=has_tmask, has_smask=has_smask),
        grid=(B, nsteps),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, bt, K), lambda b, ti: (b, ti, 0)),
            pl.BlockSpec((1, K), lambda b, ti: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, T, K), jnp.int32),
            jax.ShapeDtypeStruct((B, K), em.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((1, K), em.dtype)],
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary")),
        interpret=interpret,
    )(*inputs)


@functools.partial(jax.jit, static_argnames=("bt", "interpret"))
def viterbi_forward(log_A: jax.Array, em: jax.Array, delta0: jax.Array,
                    pad: jax.Array | None = None, *,
                    bt: int = 8, interpret: bool = False):
    """Single-sequence fused forward pass (B=1 view of the batched kernel).

    Args:
      log_A:  (K, K) transition log-probs.
      em:     (T, K) emission scores for steps 1..T (step 0 is in `delta0`).
      delta0: (K,) initial DP state.
      pad:    optional (T,) tropical-identity step mask (see batch variant).

    Returns:
      (psi, delta_T): (T, K) int32 backpointers and final (K,) DP state.
    """
    psi, delta_T = viterbi_forward_batch(
        log_A, em[None], delta0[None], None if pad is None else pad[None],
        bt=bt, interpret=interpret)
    return psi[0], delta_T[0]


#: flashprove waivers (see analysis/findings.py for the grammar).
FLASHPROVE_WAIVERS = {
    "PV201:pallas:viterbi_dp.viterbi_forward_batch": (
        "the (1, bt) pad-mask block streams bt per-step flags (32 B at the "
        "default bt=8) next to the (bt, K) emission block; its lane padding "
        "costs one tile of bandwidth per grid step, immaterial against the "
        "bt x K emission stream it rides with"),
    "PV201:pallas:viterbi_dp.viterbi_forward_batch_masked": (
        "same (1, bt) pad-mask block as viterbi_forward_batch (32 B at the "
        "default bt=8 against the bt x K emission + penalty streams); the "
        "penalty blocks themselves are lane-aligned (K multiple of 128)"),
}

__all__ = ["viterbi_forward", "viterbi_forward_batch",
           "viterbi_forward_batch_masked"]
