"""Jit'd public wrappers around the Pallas kernels.

These handle padding/tile selection/fallbacks so callers never see the kernels'
alignment constraints, and they flip to `interpret=True` automatically off-TPU
(this container validates kernels in interpret mode; on TPU the same call sites
compile the real thing).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import ref as _ref
from .tropical import tropical_matmul as _tropical_pallas
from .viterbi_dp import viterbi_forward as _vit_fwd_pallas
from .viterbi_dp import viterbi_forward_batch as _vit_fwd_batch_pallas
from .viterbi_dp import (
    viterbi_forward_batch_masked as _vit_fwd_batch_masked_pallas)
from .beam_stream import beam_step as _beam_step_pallas

_NEG = -1.0e9


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x: jax.Array, axis: int, mult: int, value) -> jax.Array:
    n = x.shape[axis]
    target = int(np.ceil(n / mult)) * mult
    if target == n:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, target - n)
    return jnp.pad(x, widths, constant_values=value)


def tropical_matmul(a: jax.Array, b: jax.Array, *, interpret: bool | None = None):
    """(max,+) product with argmax, arbitrary shapes. Returns (vals, args)."""
    if interpret is None:
        interpret = not _on_tpu()
    I, K = a.shape
    _, J = b.shape
    bi = 8 if I < 64 else 64
    bk = 8 if K < 16 else 16
    bj = 128 if J < 256 else 256
    ap = _pad_to(_pad_to(a, 0, bi, _NEG), 1, bk, _NEG)
    bp = _pad_to(_pad_to(b, 0, bk, _NEG), 1, bj, _NEG)
    vals, args = _tropical_pallas(ap, bp, bi=bi, bk=bk, bj=bj,
                                  interpret=interpret)
    args = jnp.minimum(args, K - 1)  # pad-K argmax can only win on pad rows
    return vals[:I, :J], args[:I, :J]


_ref_fwd_jit = jax.jit(_ref.viterbi_forward_ref)
_ref_fwd_masked_batch_jit = jax.jit(
    jax.vmap(_ref.viterbi_forward_masked_ref, in_axes=(None, 0, 0, 0)))


def _kernel_fits(log_A: jax.Array, K: int, bt: int, limit: int) -> bool:
    a_bytes = K * K * log_A.dtype.itemsize
    work = a_bytes + 3 * bt * K * 4 + K * K * 4  # A + streams + scores intermediate
    return K % 128 == 0 and work <= limit


def viterbi_forward(log_A: jax.Array, em: jax.Array, delta0: jax.Array, *,
                    bt: int = 8, interpret: bool | None = None,
                    vmem_limit_bytes: int = 12 * 2**20):
    """Fused Viterbi forward pass with XLA fallback when K exceeds VMEM.

    em covers steps 1..T (delta0 is step 0). Returns (psi (T,K) i32, delta_T).
    """
    if interpret is None:
        interpret = not _on_tpu()
    T, K = em.shape
    if T == 0:
        return jnp.zeros((0, K), jnp.int32), delta0
    if not _kernel_fits(log_A, K, bt, vmem_limit_bytes):
        return _ref_fwd_jit(log_A, em, delta0)  # XLA path, retrace-cached
    Tp = int(np.ceil(T / bt)) * bt
    if Tp == T:
        return _vit_fwd_pallas(log_A, em, delta0, bt=bt, interpret=interpret)
    # pad T up to a bt multiple with tropical-identity steps — exact, and keeps
    # the full block size instead of degrading the tiling on odd lengths
    em_p = jnp.pad(em, ((0, Tp - T), (0, 0)))
    pad = (jnp.arange(Tp) >= T).astype(em.dtype)
    psi, delta_T = _vit_fwd_pallas(log_A, em_p, delta0, pad, bt=bt,
                                   interpret=interpret)
    return psi[:T], delta_T


def viterbi_forward_batch(log_A: jax.Array, em: jax.Array, delta0: jax.Array,
                          lengths: jax.Array | None = None, *,
                          bt: int = 8, interpret: bool | None = None,
                          vmem_limit_bytes: int = 12 * 2**20):
    """Batched fused forward pass over (B, T, K) emissions with ragged lengths.

    One kernel launch covers the whole batch: the grid gains a batch dimension
    and `log_A` stays resident in VMEM across every sequence.  `lengths[i]`
    counts the *real* rows of `em[i]` (delta0 is step 0 and always real); the
    remaining rows run as tropical-identity steps, so per-sequence results are
    bit-identical to `viterbi_forward` on the unpadded prefix.

    Returns (psi (B, T, K) int32, delta_T (B, K)).  psi rows at padded steps
    are the identity permutation.
    """
    if interpret is None:
        interpret = not _on_tpu()
    B, T, K = em.shape
    if T == 0:
        return jnp.zeros((B, 0, K), jnp.int32), delta0
    if lengths is None:
        lengths = jnp.full((B,), T, jnp.int32)
    lengths = jnp.asarray(lengths, jnp.int32)
    if not _kernel_fits(log_A, K, bt, vmem_limit_bytes):
        pad = jnp.arange(T)[None, :] >= lengths[:, None]
        return _ref_fwd_masked_batch_jit(log_A, em, delta0, pad)
    Tp = int(np.ceil(T / bt)) * bt
    em_p = jnp.pad(em, ((0, 0), (0, Tp - T), (0, 0)))
    pad = (jnp.arange(Tp)[None, :] >= lengths[:, None]).astype(em.dtype)
    psi, delta_T = _vit_fwd_batch_pallas(log_A, em_p, delta0, pad, bt=bt,
                                         interpret=interpret)
    return psi[:, :T], delta_T


def viterbi_chunk_step(log_A: jax.Array, em_chunk: jax.Array, delta: jax.Array,
                       *, bt: int = 8, interpret: bool | None = None):
    """One streaming DP advance: carry delta through a (C, K) emission chunk.

    The online decoders feed arbitrary-length chunks; each chunk runs the same
    fused Pallas forward kernel as the offline path (log_A resident in VMEM,
    emissions streamed) instead of a per-timestep Python loop.

    Returns (psi (C, K) int32, delta' (K,)).
    """
    return viterbi_forward(log_A, em_chunk, delta, bt=bt, interpret=interpret)


def viterbi_slot_step(log_A: jax.Array, em: jax.Array, delta: jax.Array,
                      nfeed: jax.Array, *, bt: int = 8,
                      interpret: bool | None = None):
    """One inflight-batching advance: carry S slot deltas through a block.

    This is the slot-masked block step the continuous-batching scheduler
    issues once per `step()`: `em` is (S, block, K) with slot s holding
    `nfeed[s]` real emission rows (0 <= nfeed[s] <= block) followed by
    arbitrary padding.  Slots with `nfeed[s] == 0` — free slots, or live
    slots with nothing buffered — run the whole block as tropical-identity
    steps: their delta comes back bit-identical and their psi rows are the
    identity permutation.  Because the shapes (S, block, K) are fixed for
    the scheduler's lifetime, sessions joining and leaving only ever change
    array *contents*, so this traces exactly once (pinned by the retrace
    battery).

    Per-slot results are bit-identical to `viterbi_chunk_step` on the
    unpadded prefix (the batch-grid kernel's per-sequence equivalence is
    pinned by the PR 2 tests).

    Returns (psi (S, block, K) int32, delta' (S, K)).
    """
    return viterbi_forward_batch(log_A, em, delta, nfeed, bt=bt,
                                 interpret=interpret)


def viterbi_decode_fused(log_pi: jax.Array, log_A: jax.Array, em: jax.Array,
                         *, bt: int = 8, interpret: bool | None = None):
    """Full Viterbi decode using the fused forward kernel + XLA backtracking."""
    delta0 = log_pi + em[0]
    psi, delta_T = viterbi_forward(log_A, em[1:], delta0, bt=bt,
                                   interpret=interpret)
    q_last = jnp.argmax(delta_T).astype(jnp.int32)

    def back(q, psi_t):
        q_prev = psi_t[q].astype(jnp.int32)
        return q_prev, q_prev

    _, prefix = jax.lax.scan(back, q_last, psi, reverse=True)
    return jnp.concatenate([prefix, q_last[None]]), delta_T[q_last]


def viterbi_decode_fused_batch(log_pi: jax.Array, log_A: jax.Array,
                               em: jax.Array, lengths: jax.Array | None = None,
                               *, bt: int = 8, interpret: bool | None = None):
    """Batched full Viterbi decode: one batch-grid kernel launch + vmapped
    XLA backtracking.

    Args:
      em:      (B, T, K) emissions, row i real for the first lengths[i] steps.
      lengths: optional (B,) int32 true lengths (None means full length).

    Returns:
      (paths (B, T) int32, scores (B,)).  paths[i, t] for t >= lengths[i]
      repeat the sequence's final decoded state (the identity backpointers of
      the pad steps); slice to [:lengths[i]] for the true decode.
    """
    B, T, K = em.shape
    delta0 = log_pi[None, :] + em[:, 0, :]
    if T == 1:
        q = jnp.argmax(delta0, axis=1).astype(jnp.int32)
        return q[:, None], jnp.max(delta0, axis=1)
    if lengths is None:
        lengths = jnp.full((B,), T, jnp.int32)
    lengths = jnp.asarray(lengths, jnp.int32)
    psi, delta_T = viterbi_forward_batch(
        log_A, em[:, 1:], delta0, jnp.maximum(lengths - 1, 0),
        bt=bt, interpret=interpret)
    q_last = jnp.argmax(delta_T, axis=1).astype(jnp.int32)

    def back_one(q, psis):
        def back(q, psi_t):
            q_prev = psi_t[q].astype(jnp.int32)
            return q_prev, q_prev
        _, prefix = jax.lax.scan(back, q, psis, reverse=True)
        return prefix

    prefix = jax.vmap(back_one)(q_last, psi)
    paths = jnp.concatenate([prefix, q_last[:, None]], axis=1)
    scores = jnp.take_along_axis(delta_T, q_last[:, None], axis=1)[:, 0]
    return paths, scores


def _kernel_fits_masked(log_A, K: int, bt: int, limit: int,
                        has_tmask: bool, has_smask: bool) -> bool:
    a_bytes = K * K * log_A.dtype.itemsize
    work = a_bytes + 3 * bt * K * 4 + K * K * 4
    if has_tmask:
        work += 2 * K * K * 4        # resident penalty + masked-A intermediate
    if has_smask:
        work += bt * K * 4           # penalty block streamed with the emissions
    return K % 128 == 0 and work <= limit


def viterbi_forward_batch_masked(log_A: jax.Array, em: jax.Array,
                                 delta0: jax.Array,
                                 lengths: jax.Array | None = None, *,
                                 tmask=None, smask=None,
                                 bt: int = 8, interpret: bool | None = None,
                                 vmem_limit_bytes: int = 12 * 2**20):
    """Constraint-masked batched forward pass (fallback: pre-masked XLA ref).

    `tmask` (K, K) / `smask` (T, K) are additive f32 penalties ({0, NEG_INF},
    compiled by `core.constraints`); `smask` row t masks `em[:, t]` and is
    shared across the batch.  Results are bit-identical to
    `viterbi_forward_batch(log_A + tmask, em + smask, ...)` without the
    masked operands ever being materialised on the kernel path.
    """
    if interpret is None:
        interpret = not _on_tpu()
    B, T, K = em.shape
    if tmask is not None:
        tmask = jnp.asarray(tmask, em.dtype)
    if smask is not None:
        smask = jnp.asarray(smask, em.dtype)
    if T == 0:
        return jnp.zeros((B, 0, K), jnp.int32), delta0
    if lengths is None:
        lengths = jnp.full((B,), T, jnp.int32)
    lengths = jnp.asarray(lengths, jnp.int32)
    if not _kernel_fits_masked(log_A, K, bt, vmem_limit_bytes,
                               tmask is not None, smask is not None):
        pad = jnp.arange(T)[None, :] >= lengths[:, None]
        la = log_A if tmask is None else log_A + tmask
        em2 = em if smask is None else em + smask[None]
        return _ref_fwd_masked_batch_jit(la, em2, delta0, pad)
    Tp = int(np.ceil(T / bt)) * bt
    em_p = jnp.pad(em, ((0, 0), (0, Tp - T), (0, 0)))
    if smask is not None:
        smask = jnp.pad(smask, ((0, Tp - T), (0, 0)))  # pad steps: identity
    pad = (jnp.arange(Tp)[None, :] >= lengths[:, None]).astype(em.dtype)
    psi, delta_T = _vit_fwd_batch_masked_pallas(
        log_A, em_p, delta0, pad, tmask, smask, bt=bt, interpret=interpret)
    return psi[:, :T], delta_T


def viterbi_decode_fused_masked(log_pi: jax.Array, log_A: jax.Array,
                                em: jax.Array, *, t_pen=None, pi_pen=None,
                                s_pen=None, bt: int = 8,
                                interpret: bool | None = None):
    """Constrained fused decode: penalty adds fused into the DP step.

    The penalties come from `core.constraints.compiled_penalties`; every add
    here reproduces `constrain_inputs`' elementwise adds operand-for-operand,
    so the result is bit-identical to `viterbi_decode_fused` over the
    pre-masked inputs.
    """
    if pi_pen is not None:
        log_pi = log_pi + jnp.asarray(pi_pen, log_pi.dtype)
    em0 = em[0]
    smask = None
    if s_pen is not None:
        s_pen = jnp.asarray(s_pen, em.dtype)
        em0 = em0 + s_pen[0]
        smask = s_pen[1:]
    delta0 = log_pi + em0
    psi, delta_T = viterbi_forward_batch_masked(
        log_A, em[None, 1:], delta0[None], tmask=t_pen, smask=smask,
        bt=bt, interpret=interpret)
    psi, delta_T = psi[0], delta_T[0]
    q_last = jnp.argmax(delta_T).astype(jnp.int32)

    def back(q, psi_t):
        q_prev = psi_t[q].astype(jnp.int32)
        return q_prev, q_prev

    _, prefix = jax.lax.scan(back, q_last, psi, reverse=True)
    return jnp.concatenate([prefix, q_last[None]]), delta_T[q_last]


def viterbi_decode_fused_batch_masked(log_pi: jax.Array, log_A: jax.Array,
                                      em: jax.Array,
                                      lengths: jax.Array | None = None, *,
                                      t_pen=None, pi_pen=None, s_pen=None,
                                      bt: int = 8,
                                      interpret: bool | None = None):
    """Constrained batched fused decode (ragged lengths, shared schedule).

    The per-step penalty indexes *absolute* step t, so ragged tails simply
    never reach the later rows; pad steps stay tropical-identity.  Bit-
    identical to `viterbi_decode_fused_batch` over pre-masked inputs.
    """
    B, T, K = em.shape
    if pi_pen is not None:
        log_pi = log_pi + jnp.asarray(pi_pen, log_pi.dtype)
    em0 = em[:, 0, :]
    smask = None
    if s_pen is not None:
        s_pen = jnp.asarray(s_pen, em.dtype)
        em0 = em0 + s_pen[0][None]
        smask = s_pen[1:]
    delta0 = log_pi[None, :] + em0
    if T == 1:
        q = jnp.argmax(delta0, axis=1).astype(jnp.int32)
        return q[:, None], jnp.max(delta0, axis=1)
    if lengths is None:
        lengths = jnp.full((B,), T, jnp.int32)
    lengths = jnp.asarray(lengths, jnp.int32)
    psi, delta_T = viterbi_forward_batch_masked(
        log_A, em[:, 1:], delta0, jnp.maximum(lengths - 1, 0),
        tmask=t_pen, smask=smask, bt=bt, interpret=interpret)
    q_last = jnp.argmax(delta_T, axis=1).astype(jnp.int32)

    def back_one(q, psis):
        def back(q, psi_t):
            q_prev = psi_t[q].astype(jnp.int32)
            return q_prev, q_prev
        _, prefix = jax.lax.scan(back, q, psis, reverse=True)
        return prefix

    prefix = jax.vmap(back_one)(q_last, psi)
    paths = jnp.concatenate([prefix, q_last[:, None]], axis=1)
    scores = jnp.take_along_axis(delta_T, q_last[:, None], axis=1)[:, 0]
    return paths, scores


@functools.partial(jax.jit, static_argnames=("width",))
def viterbi_decode_banded(log_pi: jax.Array, log_A: jax.Array, em: jax.Array,
                          centers, *, width: int):
    """Banded Viterbi decode: O(T * Kb^2) work, Kb = 2*width+1 window.

    At step t only states within `width` of `centers[t]` (clipped into
    [0, K-1]) are legal — the `BandConstraint` semantics.  The DP slides a
    contiguous Kb window over the state axis (`lax.dynamic_slice` of the
    (Kb, Kb) transition block per step), so K-wide rows are never
    materialised: live state is the Kb frontier plus T windows of local
    backpointers (`core.constraints.banded_state_bytes`).

    Bit-identity with the dense masked decode holds because (a) the window
    always contains the whole allowed band, (b) the in-window penalty add is
    the same `em + s_pen` elementwise add the dense path performs, and (c)
    out-of-band states sit >= ~1e9 below every in-band score (NEG_INF is a
    finite sentinel), so they can neither win nor tie a max/argmax, and the
    contiguous window preserves dense argmax tie order.  Requires in-band
    states to keep feasible paths (dense `log_A`) — with sparse transitions,
    pre-mask `log_A` instead.

    Returns (path (T,) int32 of *global* state ids, score).
    """
    T, K = em.shape
    w = int(width)
    Kb = min(2 * w + 1, K)
    centers = jnp.clip(jnp.asarray(centers, jnp.int32)[:T], 0, K - 1)
    starts = jnp.clip(centers - w, 0, K - Kb).astype(jnp.int32)
    offs = jnp.arange(Kb, dtype=jnp.int32)

    def win_pen(c, start):
        idx = start + offs
        return jnp.where(jnp.abs(idx - c) <= w,
                         jnp.asarray(0.0, em.dtype),
                         jnp.asarray(_NEG, em.dtype))

    s0 = starts[0]
    d0 = (jax.lax.dynamic_slice(log_pi, (s0,), (Kb,))
          + (jax.lax.dynamic_slice(em[0], (s0,), (Kb,))
             + win_pen(centers[0], s0)))

    def step(carry, inp):
        delta_w, prev_start = carry
        c, start, em_t = inp
        a_sub = jax.lax.dynamic_slice(log_A, (prev_start, start), (Kb, Kb))
        scores = delta_w[:, None] + a_sub
        psi = jnp.argmax(scores, axis=0).astype(jnp.int32)
        em_w = (jax.lax.dynamic_slice(em_t, (start,), (Kb,))
                + win_pen(c, start))
        new = jnp.max(scores, axis=0) + em_w
        return (new, start), psi

    (delta_w, _), psis = jax.lax.scan(
        step, (d0, s0), (centers[1:], starts[1:], em[1:]))
    q_loc = jnp.argmax(delta_w).astype(jnp.int32)

    def back(q, psi_t):
        q_prev = psi_t[q].astype(jnp.int32)
        return q_prev, q_prev

    _, prefix = jax.lax.scan(back, q_loc, psis, reverse=True)
    loc = jnp.concatenate([prefix, q_loc[None]])
    return (starts + loc).astype(jnp.int32), delta_w[q_loc]


def beam_step(log_A: jax.Array, em_t: jax.Array, scores: jax.Array,
              states: jax.Array, *, chunk: int = 256,
              interpret: bool | None = None):
    """Streaming dynamic-beam step, arbitrary K (padded to chunk)."""
    if interpret is None:
        interpret = not _on_tpu()
    K = log_A.shape[0]
    chunk = min(chunk, int(np.ceil(K / 128)) * 128)
    Ap = _pad_to(_pad_to(log_A, 0, chunk, _NEG * 4), 1, chunk, _NEG * 4)
    em_p = _pad_to(em_t, 0, chunk, _NEG * 4)
    return _beam_step_pallas(Ap, em_p, scores, states, chunk=chunk,
                             interpret=interpret)


__all__ = ["tropical_matmul", "viterbi_forward", "viterbi_forward_batch",
           "viterbi_forward_batch_masked", "viterbi_chunk_step",
           "viterbi_slot_step", "viterbi_decode_fused",
           "viterbi_decode_fused_batch", "viterbi_decode_fused_masked",
           "viterbi_decode_fused_batch_masked", "viterbi_decode_banded",
           "beam_step"]
