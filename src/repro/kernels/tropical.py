"""Tropical (max, +) matrix product Pallas TPU kernel, with argmax backpointers.

    C[i, j]   = max_k (A[i, k] + B[k, j])
    arg[i, j] = argmax_k (A[i, k] + B[k, j])

This is the inner operation of every Viterbi DP step (A = batched delta vectors,
B = transition matrix) and of the associative-scan schedule (A, B = tropical
matrix products).  It cannot use the MXU — (max, +) is a semiring, not a ring —
so the kernel is laid out for the VPU: 8x128-aligned tiles, a 3-D grid
(I/bi, J/bj, K/bk) with the contraction dimension innermost, and the running
(max, argmax) accumulator held in the revisited output block in VMEM.

VMEM budget per grid step (defaults bi=64, bk=16, bj=256, fp32):
    A tile 64*16*4 = 4 KiB, B tile 16*256*4 = 16 KiB,
    broadcast intermediate 64*16*256*4 = 1 MiB, C/arg tiles 2*64*256*4 = 128 KiB
comfortably under the 16 MiB/core budget, leaving room for the Pallas pipeline's
double-buffered input blocks (the hardware analogue of the paper's double-buffered
BRAM scheme).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _tropical_kernel(a_ref, b_ref, c_ref, arg_ref, *, bk: int):
    k = pl.program_id(2)

    a = a_ref[...]          # (bi, bk)
    b = b_ref[...]          # (bk, bj)
    s = a[:, :, None] + b[None, :, :]            # (bi, bk, bj)
    m = jnp.max(s, axis=1)                       # (bi, bj)
    arg = jnp.argmax(s, axis=1).astype(jnp.int32) + k * bk

    @pl.when(k == 0)
    def _init():
        c_ref[...] = m
        arg_ref[...] = arg

    @pl.when(k > 0)
    def _update():
        prev = c_ref[...]
        take = m > prev
        c_ref[...] = jnp.where(take, m, prev)
        arg_ref[...] = jnp.where(take, arg, arg_ref[...])


@functools.partial(jax.jit, static_argnames=("bi", "bk", "bj", "interpret"))
def tropical_matmul(a: jax.Array, b: jax.Array, *, bi: int = 64, bk: int = 16,
                    bj: int = 256, interpret: bool = False):
    """(max, +) product of (I, K) x (K, J) -> values (I, J), argmax (I, J) int32.

    Shapes must divide the tile sizes; `ops.tropical_matmul` pads arbitrary
    shapes and picks tiles.
    """
    I, K = a.shape
    K2, J = b.shape
    assert K == K2, (a.shape, b.shape)
    assert I % bi == 0 and K % bk == 0 and J % bj == 0, (a.shape, b.shape, (bi, bk, bj))

    grid = (I // bi, J // bj, K // bk)
    return pl.pallas_call(
        functools.partial(_tropical_kernel, bk=bk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bi, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bj), lambda i, j, k: (k, j)),
        ],
        out_specs=[
            pl.BlockSpec((bi, bj), lambda i, j, k: (i, j)),
            pl.BlockSpec((bi, bj), lambda i, j, k: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((I, J), a.dtype),
            jax.ShapeDtypeStruct((I, J), jnp.int32),
        ],
        interpret=interpret,
    )(a, b)


#: flashprove waivers (see analysis/findings.py for the grammar).
FLASHPROVE_WAIVERS = {
    "PV201:pallas:tropical.tropical_matmul": (
        "the contraction tile bk=16 keeps small-K (max,+) products from "
        "padding K up to 128 and recomputing 8x; the lane padding it costs "
        "on the A block is accepted until the roadmap tropical-MXU item "
        "restructures this kernel around (8, 128)-aligned MXU tiles"),
}

__all__ = ["tropical_matmul"]
