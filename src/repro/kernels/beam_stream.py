"""Streaming dynamic-beam-search step — Pallas TPU kernel.

One FLASH-BS timestep: expand the B-wide beam against a chunk of C target states
at a time, keeping only the running top-B in VMEM scratch.  This is the TPU
adaptation of the paper's double-buffered min-heap pair (Sec. V-C-2): the
running beam plays `heap_total`, the incoming beam `heap_pre`, and the merge is
a vectorised select instead of sift-down — same O(B) live state, no scalar ops.

Per grid step (one chunk of C targets):
  * the (K, C) column block of log_A streams HBM->VMEM via the Pallas pipeline;
  * beam rows are gathered with a one-hot matmul (MXU-friendly, avoids dynamic
    gathers): rows = onehot(states, K) @ A_block                (B, C);
  * candidates cand[b, c] = score[b] + rows[b, c] + em[c];
  * per-target reduction over the beam, then a B-round vectorised selection
    merges (C candidates + running B) back into the top-B scratch.

Grid iteration is sequential, so the scratch beam carries across chunks; the
final chunk writes the new beam out.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax<0.5 exposes this dataclass as TPUCompilerParams
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

_SENTINEL = -4.0e9


def _select_top_b(vals, idxs, froms, B: int):
    """Vectorised top-B selection from (N,) candidates (N = B + C)."""
    N = vals.shape[0]
    iota_b = jnp.arange(B, dtype=jnp.int32)

    def body(i, carry):
        vals_m, out_v, out_i, out_f = carry
        m = jnp.max(vals_m)
        am = jnp.argmax(vals_m).astype(jnp.int32)
        sel = iota_b == i
        out_v = jnp.where(sel, m, out_v)
        out_i = jnp.where(sel, idxs[am], out_i)
        out_f = jnp.where(sel, froms[am], out_f)
        vals_m = jnp.where(jnp.arange(N) == am, _SENTINEL * 2, vals_m)
        return vals_m, out_v, out_i, out_f

    init = (vals,
            jnp.full((B,), _SENTINEL, vals.dtype),
            jnp.zeros((B,), jnp.int32),
            jnp.zeros((B,), jnp.int32))
    _, out_v, out_i, out_f = jax.lax.fori_loop(0, B, body, init)
    return out_v, out_i, out_f


def _beam_step_kernel(a_ref, em_ref, scores_ref, states_ref,
                      out_s_ref, out_st_ref, out_f_ref,
                      run_s, run_st, run_f, *, B: int, C: int, K: int,
                      nchunks: int):
    c = pl.program_id(0)

    @pl.when(c == 0)
    def _seed():
        run_s[...] = jnp.full((B,), _SENTINEL, run_s.dtype)
        run_st[...] = jnp.zeros((B,), jnp.int32)
        run_f[...] = jnp.zeros((B,), jnp.int32)

    scores = scores_ref[...]                    # (B,)
    states = states_ref[...]                    # (B,) int32
    a_blk = a_ref[...]                          # (K, C) column block
    em_c = em_ref[...]                          # (C,)

    onehot = (states[:, None] == jnp.arange(K, dtype=jnp.int32)[None, :])
    rows = jax.lax.dot_general(
        onehot.astype(a_blk.dtype), a_blk,
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)      # (B, C)

    cand = scores[:, None] + rows + em_c[None, :]
    best = jnp.max(cand, axis=0)                               # (C,)
    from_b = jnp.argmax(cand, axis=0).astype(jnp.int32)        # (C,)
    tgt = (c * C + jnp.arange(C)).astype(jnp.int32)

    vals = jnp.concatenate([run_s[...], best])
    idxs = jnp.concatenate([run_st[...], tgt])
    froms = jnp.concatenate([run_f[...], from_b])
    nv, ni, nf = _select_top_b(vals, idxs, froms, B)
    run_s[...] = nv
    run_st[...] = ni
    run_f[...] = nf

    @pl.when(c == nchunks - 1)
    def _emit():
        out_s_ref[...] = run_s[...]
        out_st_ref[...] = run_st[...]
        out_f_ref[...] = run_f[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def beam_step(log_A: jax.Array, em_t: jax.Array, scores: jax.Array,
              states: jax.Array, *, chunk: int = 256, interpret: bool = False):
    """One dynamic-beam transition.

    Args:
      log_A:  (K, K) transitions; K multiple of `chunk`.
      em_t:   (K,) emissions at this step.
      scores/states: (B,) current beam.

    Returns:
      (new_scores, new_states, from_slots) — each (B,).
    """
    K = log_A.shape[0]
    B = scores.shape[0]
    assert K % chunk == 0, (K, chunk)
    nchunks = K // chunk

    return pl.pallas_call(
        functools.partial(_beam_step_kernel, B=B, C=chunk, K=K,
                          nchunks=nchunks),
        grid=(nchunks,),
        in_specs=[
            pl.BlockSpec((K, chunk), lambda c: (0, c)),  # A column block
            pl.BlockSpec((chunk,), lambda c: (c,)),
            pl.BlockSpec((B,), lambda c: (0,)),
            pl.BlockSpec((B,), lambda c: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((B,), lambda c: (0,)),
            pl.BlockSpec((B,), lambda c: (0,)),
            pl.BlockSpec((B,), lambda c: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B,), scores.dtype),
            jax.ShapeDtypeStruct((B,), jnp.int32),
            jax.ShapeDtypeStruct((B,), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((B,), scores.dtype),
            pltpu.VMEM((B,), jnp.int32),
            pltpu.VMEM((B,), jnp.int32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(log_A, em_t, scores, states)


__all__ = ["beam_step"]
