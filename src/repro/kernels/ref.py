"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic specification its kernel is tested against
(tests/test_kernels.py sweeps shapes/dtypes and asserts allclose).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def tropical_matmul_ref(a: jax.Array, b: jax.Array):
    """(I,K) x (K,J) -> ((I,J) max values, (I,J) int32 argmax over K)."""
    s = a[:, :, None] + b[None, :, :]
    return jnp.max(s, axis=1), jnp.argmax(s, axis=1).astype(jnp.int32)


def viterbi_forward_ref(log_A: jax.Array, em: jax.Array, delta0: jax.Array):
    """Reference for kernels.viterbi_dp.viterbi_forward."""
    def step(delta, em_t):
        scores = delta[:, None] + log_A
        psi = jnp.argmax(scores, axis=0).astype(jnp.int32)
        return jnp.max(scores, axis=0) + em_t, psi

    delta_T, psis = jax.lax.scan(step, delta0, em)
    return psis, delta_T


def viterbi_forward_masked_ref(log_A: jax.Array, em: jax.Array,
                               delta0: jax.Array, pad: jax.Array):
    """Reference for the kernel's tropical-identity pad steps.

    `pad` is a (T,) bool mask; masked steps freeze delta and emit identity
    backpointers, so the result is bit-identical to running the unmasked
    recursion on the unpadded prefix.
    """
    K = log_A.shape[0]
    eye = jnp.arange(K, dtype=jnp.int32)

    def step(delta, inp):
        em_t, is_pad = inp
        scores = delta[:, None] + log_A
        psi = jnp.argmax(scores, axis=0).astype(jnp.int32)
        new = jnp.max(scores, axis=0) + em_t
        return jnp.where(is_pad, delta, new), jnp.where(is_pad, eye, psi)

    delta_T, psis = jax.lax.scan(step, delta0, (em, pad))
    return psis, delta_T


def viterbi_forward_masked_pen_ref(log_A: jax.Array, em: jax.Array,
                                   delta0: jax.Array, pad: jax.Array,
                                   tmask: jax.Array | None = None,
                                   smask: jax.Array | None = None):
    """Reference for `viterbi_dp.viterbi_forward_batch_masked` (one sequence).

    The constraint penalties are *additive* ({0, NEG_INF} f32, see
    `core.constraints`), so the reference is exactly the pad-masked recursion
    over the pre-masked inputs — elementwise adds here and per-row adds in
    the kernel produce identical bits, which is what makes the masked kernel
    interchangeable with `constrain_inputs` + the dense path.
    """
    if tmask is not None:
        log_A = log_A + tmask
    if smask is not None:
        em = em + smask
    return viterbi_forward_masked_ref(log_A, em, delta0, pad)


def beam_step_ref(log_A: jax.Array, em_t: jax.Array, scores: jax.Array,
                  states: jax.Array):
    """Reference for kernels.beam_stream.beam_step.

    Candidate targets are reduced over the beam; ties broken toward the lower
    beam slot / lower target id, matching the kernel's selection order.
    """
    B = scores.shape[0]
    cand = scores[:, None] + log_A[states] + em_t[None, :]     # (B, K)
    best = jnp.max(cand, axis=0)
    from_b = jnp.argmax(cand, axis=0).astype(jnp.int32)
    top_s, top_st = jax.lax.top_k(best, B)
    return top_s, top_st.astype(jnp.int32), from_b[top_st]


__all__ = ["tropical_matmul_ref", "viterbi_forward_ref",
           "viterbi_forward_masked_ref", "viterbi_forward_masked_pen_ref",
           "beam_step_ref"]
