"""Pallas TPU kernels for the FLASH Viterbi hot paths.

Layout per EXAMPLE.md: one <name>.py per kernel (pl.pallas_call + BlockSpec),
ops.py jit'd wrappers with padding/fallbacks, ref.py pure-jnp oracles.
"""

from . import ops, ref
from .tropical import tropical_matmul as tropical_matmul_pallas
from .viterbi_dp import viterbi_forward as viterbi_forward_pallas
from .viterbi_dp import viterbi_forward_batch as viterbi_forward_batch_pallas
from .beam_stream import beam_step as beam_step_pallas

__all__ = ["ops", "ref", "tropical_matmul_pallas", "viterbi_forward_pallas",
           "viterbi_forward_batch_pallas", "beam_step_pallas"]
