"""Logical -> physical sharding rules.

Model code annotates every parameter and activation with *logical* axis names
("batch", "seq", "model_d", "ff", "heads", "kv_heads", "vocab", "experts", ...).
A `ShardingRules` table maps those to physical mesh axes; the same model code
then runs on the single-pod (data, model) mesh, the multi-pod
(pod, data, model) mesh, or a test mesh, by swapping the table.

Conventions (MaxText-style megatron sharding):
  * batch          -> ("pod", "data")   pure DP; never crosses TP groups
  * heads/ff/vocab/experts -> "model"   tensor/expert parallelism
  * seq            -> "data" only for the long-context decode cells (batch=1),
                      where the KV cache / recurrent state is sequence-sharded
  * everything else replicated
"""

from __future__ import annotations

import dataclasses

from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Mapping from logical axis name to mesh axis (str, tuple, or None)."""
    rules: dict

    def axis(self, name: str | None):
        if name is None:
            return None
        return self.rules.get(name, None)

    def spec(self, *logical_axes: str | None) -> P:
        return P(*(self.axis(a) for a in logical_axes))


SINGLE_POD_RULES = ShardingRules(rules={
    "batch": "data",
    "seq_sharded": "data",      # long-context: sequence over the data axis
    "heads": "model",
    "kv_heads": "model",
    "ff": "model",
    "vocab": "model",
    "experts": "model",
    "expert_ff": None,          # decode opt "moe2d": -> "data" (2-D weights)
    "model_d": None,            # d_model replicated (no sequence parallel here)
    "seq": None,
})

MULTI_POD_RULES = ShardingRules(rules={
    "batch": ("pod", "data"),
    "seq_sharded": "data",      # sequence sharding stays intra-pod (ICI, not DCI)
    "heads": "model",
    "kv_heads": "model",
    "ff": "model",
    "vocab": "model",
    "experts": "model",
    "expert_ff": None,
    "model_d": None,
    "seq": None,
})


def logical(rules: ShardingRules, *axes: str | None) -> P:
    return rules.spec(*axes)


def spec_tree_from_layout(rules: ShardingRules, layout: dict) -> dict:
    """Build a PartitionSpec tree mirroring a param layout table.

    layout: {name: (shape, logical_axes, init_kind)} possibly nested.
    """
    out = {}
    for name, val in layout.items():
        if isinstance(val, dict):
            out[name] = spec_tree_from_layout(rules, val)
        else:
            _, axes, _ = val
            out[name] = rules.spec(*axes)
    return out


__all__ = ["ShardingRules", "SINGLE_POD_RULES", "MULTI_POD_RULES", "logical",
           "spec_tree_from_layout"]
