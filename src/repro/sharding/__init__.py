"""Mesh axes, logical->physical sharding rules, and spec helpers."""

from .rules import (ShardingRules, SINGLE_POD_RULES, MULTI_POD_RULES,
                    logical, spec_tree_from_layout)

__all__ = ["ShardingRules", "SINGLE_POD_RULES", "MULTI_POD_RULES", "logical",
           "spec_tree_from_layout"]
