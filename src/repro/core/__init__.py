"""FLASH Viterbi core: the paper's algorithms, baselines, and the HMM substrate."""

from .hmm import (HMM, NEG_INF, erdos_renyi_hmm, left_to_right_hmm,
                  sample_observations, path_score, relative_error,
                  random_emissions)
from .vanilla import (viterbi_vanilla, viterbi_vanilla_masked,
                      viterbi_vanilla_batched)
from .checkpoint_viterbi import viterbi_checkpoint
from .flash import flash_viterbi, plan_padding, pad_emissions, chunked_vmap
from .flash_bs import flash_bs_viterbi
from .beam_static import beam_static_viterbi, beam_static_mp_viterbi
from .assoc import viterbi_assoc
from .online import (OnlineViterbiDecoder, OnlineBeamDecoder,
                     SlotViterbiDecoder, viterbi_online, viterbi_online_beam)
from .constraints import (ConstraintSpec, TransitionMaskConstraint,
                          BandConstraint, LexiconConstraint,
                          ScheduleConstraint, constrain_inputs,
                          compiled_penalties, with_constraint,
                          banded_state_bytes)
from .spec import (ResourceBudget, DecodeSpec, VanillaSpec, CheckpointSpec,
                   FlashSpec, FlashBSSpec, BeamStaticSpec, BeamStaticMPSpec,
                   AssocSpec, FusedSpec, OnlineSpec, OnlineBeamSpec,
                   SPEC_BY_METHOD, spec_from_tunables, as_decode_spec)
from .planner import (decoder_state_bytes, spec_state_bytes, DecodePlan, plan,
                      online_session_bytes, inflight_state_bytes,
                      AdmissionPlan, plan_admission)
from .decoder import ViterbiDecoder
from .api import (viterbi_decode, viterbi_decode_hmm, viterbi_decode_batch,
                  METHODS, BATCH_METHODS)

__all__ = [
    "HMM", "NEG_INF", "erdos_renyi_hmm", "left_to_right_hmm",
    "sample_observations", "path_score", "relative_error", "random_emissions",
    "viterbi_vanilla", "viterbi_vanilla_masked", "viterbi_vanilla_batched",
    "viterbi_checkpoint",
    "flash_viterbi", "plan_padding", "pad_emissions", "chunked_vmap",
    "flash_bs_viterbi", "beam_static_viterbi", "beam_static_mp_viterbi",
    "viterbi_assoc", "OnlineViterbiDecoder", "OnlineBeamDecoder",
    "SlotViterbiDecoder", "viterbi_online", "viterbi_online_beam",
    # constrained decoding
    "ConstraintSpec", "TransitionMaskConstraint", "BandConstraint",
    "LexiconConstraint", "ScheduleConstraint", "constrain_inputs",
    "compiled_penalties", "with_constraint", "banded_state_bytes",
    # typed spec / planner / decoder API
    "ResourceBudget", "DecodeSpec", "VanillaSpec", "CheckpointSpec",
    "FlashSpec", "FlashBSSpec", "BeamStaticSpec", "BeamStaticMPSpec",
    "AssocSpec", "FusedSpec", "OnlineSpec", "OnlineBeamSpec",
    "SPEC_BY_METHOD", "spec_from_tunables", "as_decode_spec",
    "decoder_state_bytes", "spec_state_bytes", "DecodePlan", "plan",
    "online_session_bytes", "inflight_state_bytes",
    "AdmissionPlan", "plan_admission",
    "ViterbiDecoder",
    # legacy string dispatch (thin shim over the specs)
    "viterbi_decode", "viterbi_decode_hmm", "viterbi_decode_batch",
    "METHODS", "BATCH_METHODS",
]
