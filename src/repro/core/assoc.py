"""Associative-scan Viterbi over the tropical (max, +) semiring — beyond-paper.

Viterbi's DP recurrence is a chain of matrix products in the (max, +) semiring:
    delta_t = delta_{t-1} (x) M_t,    M_t[i, j] = log A[i, j] + em[t, j].
Matrix (x) is associative, so `lax.associative_scan` evaluates all prefixes in
O(log T) depth — a parallelisation axis the paper's CPU-thread / FPGA targets
cannot afford (it inflates work by a factor K: O(K^3 T) total), but which a
256-chip pod can when K is small and T is large.  Included as an alternative
schedule; the roofline comparison vs FLASH is in EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _tropical_matmul(a, b):
    """(max, +) matrix product with leading batch dims."""
    return jnp.max(a[..., :, :, None] + b[..., None, :, :], axis=-2)


@jax.jit
def viterbi_assoc(log_pi, log_A, em):
    """Exact Viterbi via tropical associative scan.  O(K^3 T / P) work, O(log T)
    depth, O(T K^2) memory — small-K / huge-T regime only."""
    T, K = em.shape
    Ms = log_A[None, :, :] + em[1:, None, :]                  # (T-1, K, K)
    F = jax.lax.associative_scan(_tropical_matmul, Ms)        # prefix products
    d0 = log_pi + em[0]
    deltas_tail = jnp.max(d0[None, :, None] + F, axis=1)      # (T-1, K)
    deltas = jnp.concatenate([d0[None], deltas_tail])         # (T, K)

    q_last = jnp.argmax(deltas[-1]).astype(jnp.int32)
    score = deltas[-1, q_last]

    def back(q, delta_prev):
        q_prev = jnp.argmax(delta_prev + log_A[:, q]).astype(jnp.int32)
        return q_prev, q_prev

    _, path_prefix = jax.lax.scan(back, q_last, deltas[:-1], reverse=True)
    path = jnp.concatenate([path_prefix, q_last[None]])
    return path, score


#: flashprove waivers (see analysis/findings.py for the grammar).
FLASHPROVE_WAIVERS = {
    "PV103:jaxpr:assoc": (
        "associative_scan combines ~T/2 tropical matmul pairs per level, "
        "each materializing a (pairs, K, K, K) broadcast that XLA fuses "
        "into the max-reduction; O(T K^2) products are the documented, "
        "modeled cost of the assoc method (decoder_state_bytes = T K^2 4) "
        "and the K^3 broadcast is its transient working set"),
}

__all__ = ["viterbi_assoc"]
