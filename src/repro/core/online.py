"""On-line (streaming) Viterbi decoding with convergence-point commitment.

Every decoder in this package so far is *offline*: it sees the full (T, K)
emission matrix before emitting a single state.  This module adds the
streaming counterpart (Šrámek, Brejová & Vinař's On-line Viterbi, adapted to
the FLASH substrate): emissions arrive in chunks, and committed path prefixes
are returned as soon as they are provably final.

The key observation is that the backpointer maps psi_t : states(t) -> states(t-1)
form a function composition; once the composition of the maps from the current
frontier back to some past time tau collapses to a *single* value, every
surviving hypothesis — including whichever one eventually wins — passes through
that state.  The prefix up to tau is therefore exact and can be emitted and its
backpointers freed.  Expected uncommitted-window length is O(K log K) for
well-behaved models (the on-line Viterbi bound), so live memory is decoupled
from T.

Two variants:

  * ``OnlineViterbiDecoder`` — exact.  The per-chunk DP runs through
    ``kernels.ops.viterbi_chunk_step``, i.e. the same fused Pallas forward
    kernel as the offline path (transition matrix VMEM-resident, emissions
    streamed), not a per-timestep Python loop.  With ``max_lag=None`` the
    assembled path is bit-identical to ``viterbi_vanilla``.

  * ``OnlineBeamDecoder`` — FLASH-BS's compact O(B) beam state made
    streaming.  Reuses ``flash_bs._beam_transition`` (the chunked streaming
    top-B merge); the convergence check composes the per-step *beam-slot*
    backpointers, so live state is O(W * B), independent of K.

Both support a bounded-lag forced flush: if the uncommitted window exceeds
``max_lag`` steps, the oldest states are committed along the currently-best
hypothesis (the standard fixed-lag approximation).  Hypotheses inconsistent
with a forced commit are masked out afterwards so later commits stay
consistent with what was already emitted.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .hmm import NEG_INF
from .flash_bs import _SENTINEL, _beam_transition, _stream_top_b


# ---------------------------------------------------------------------------
# Shared window algebra
# ---------------------------------------------------------------------------

def _latest_convergence(rows: list[np.ndarray], lo: int):
    """Latest row index i >= lo at which the pointer composition collapses.

    ``rows[i]`` maps identities at time base+i to identities at time base+i-1.
    Walking backward from the frontier, the first time the composed image is a
    single value is the *latest* convergence point (a collapsed composition
    stays collapsed further back).  Returns (i, value) or (None, None).
    """
    if len(rows) == 0:
        return None, None
    cur = np.arange(rows[-1].shape[0])
    for i in range(len(rows) - 1, -1, -1):
        cur = rows[i][cur]
        if i >= lo and (cur == cur[0]).all():
            return i, int(cur[0])
    return None, None


class _StreamingDecoder:
    """Commit/window bookkeeping shared by the exact and beam decoders.

    Subclasses provide the DP carry and the pointer rows; this base tracks the
    committed prefix, the window base time, lag statistics and forced flushes.
    Window row i always maps (state or slot) at absolute time ``_base + i`` to
    its predecessor at ``_base + i - 1``; committed states cover times
    ``0 .. n_committed - 1`` and ``_base == max(n_committed, 1)``.
    """

    def __init__(self, max_lag: int | None):
        if max_lag is not None and max_lag < 1:
            raise ValueError(f"max_lag must be >= 1, got {max_lag}")
        self.max_lag = max_lag
        self._committed: list[int] = []
        self._t = 0          # total timesteps fed
        self._base = 1
        self._finished = False
        self.score: float | None = None
        self.stats = {"feeds": 0, "commits": 0, "forced": 0, "peak_lag": 0}

    # -- subclass surface ---------------------------------------------------
    def _rows(self) -> list[np.ndarray]:
        raise NotImplementedError

    def _drop_rows(self, n: int) -> None:
        raise NotImplementedError

    def _frontier_best(self) -> tuple[int, float]:
        """(identity at time t-1 of the best hypothesis, its score)."""
        raise NotImplementedError

    def _identity_to_state(self, i_row_plus_1: int, ident: int) -> int:
        """Map a window identity (row index + 1 convention, see _collect)."""
        raise NotImplementedError

    def _mask_inconsistent(self, f_ident: int) -> None:
        """Suppress hypotheses whose ancestor at the new base-1 != f_ident."""
        raise NotImplementedError

    # -- shared machinery ---------------------------------------------------
    @property
    def n_committed(self) -> int:
        return len(self._committed)

    @property
    def lag(self) -> int:
        """Number of fed timesteps whose state has not been committed yet."""
        return self._t - self.n_committed

    @property
    def path(self) -> np.ndarray:
        """States committed so far (a prefix of the final decoded path)."""
        # flashlint: disable=FL002(committed prefix is a host-side python list, no device sync)
        return np.asarray(self._committed, dtype=np.int32)

    def _lo(self) -> int:
        # lowest row index whose composition tells us something new
        return self.n_committed - self._base + 1

    def _collect(self, rows, i_top: int, ident: int) -> tuple[list[int], int]:
        """Backtrack ``ident`` (at time _base + i_top) down to time n_committed.

        Returns (states oldest-first, identity at the oldest time).
        """
        lo = self._lo()
        seg = [self._identity_to_state(i_top + 1, ident)]
        for i in range(i_top, lo - 1, -1):
            ident = int(rows[i][ident])
            seg.append(self._identity_to_state(i, ident))
        seg.reverse()
        return seg, ident

    def _try_commit(self) -> list[int]:
        rows = self._rows()
        i_conv, ident = _latest_convergence(rows, self._lo())
        if i_conv is None:
            return []
        seg, _ = self._collect(rows, i_conv - 1, ident)
        self._committed.extend(seg)
        self._drop_rows(i_conv)
        self._base += i_conv
        self.stats["commits"] += 1
        return seg

    def _force_flush(self, m: int) -> list[int]:
        """Commit the oldest ``m`` window steps along the best hypothesis."""
        rows = self._rows()
        ident, _ = self._frontier_best()
        seg, _ = self._collect(rows, len(rows) - 1, ident)
        seg = seg[:m]
        self._committed.extend(seg)
        drop = self.n_committed - self._base  # rows for times <= n_committed-1
        self._drop_rows(drop)
        self._base += drop
        # pin future hypotheses to the committed seam state
        f_state = seg[-1]
        self._mask_inconsistent(f_state)
        self.stats["forced"] += 1
        return seg

    def _after_feed(self) -> np.ndarray:
        self.stats["feeds"] += 1
        new = self._try_commit()
        if self.max_lag is not None and self.lag > self.max_lag:
            new += self._force_flush(self.lag - self.max_lag)
        self.stats["peak_lag"] = max(self.stats["peak_lag"], self.lag)
        # flashlint: disable=FL002(newly committed states are a host list)
        return np.asarray(new, dtype=np.int32)

    def flush(self) -> tuple[np.ndarray, float]:
        """Commit everything fed so far; returns (tail states, path score).

        After flush the decoder is finished; ``path`` holds the full decode.
        """
        if self._finished:
            return np.zeros((0,), np.int32), self.score
        self._finished = True
        if self._t == 0:
            self.score = float("nan")
            return np.zeros((0,), np.int32), self.score
        rows = self._rows()
        ident, score = self._frontier_best()
        seg, _ = self._collect(rows, len(rows) - 1, ident)
        self._committed.extend(seg)
        self._drop_rows(len(rows))
        self._base = self._t
        self.score = score
        # flashlint: disable=FL002(flush tail is a host list)
        return np.asarray(seg, dtype=np.int32), score

    def _check_open(self, chunk) -> None:
        if self._finished:
            raise RuntimeError("decoder already flushed")
        if chunk.ndim != 2:
            raise ValueError(f"expected (C, K) chunk, got shape {chunk.shape}")


# ---------------------------------------------------------------------------
# Exact streaming decoder
# ---------------------------------------------------------------------------

class _ExactWindow(_StreamingDecoder):
    """Window plumbing shared by the exact decoders (identities == states).

    Subclasses own the DP frontier (`_frontier_best`) and how an
    inconsistency mask reaches the scores (`_mask_inconsistent`); this base
    owns the (W, K) backpointer window itself.
    """

    K: int

    def __init__(self, max_lag: int | None):
        super().__init__(max_lag)
        self._psis: list[np.ndarray] = []   # each (c, K); together rows base..t-1

    def _rows(self) -> list[np.ndarray]:
        if len(self._psis) > 1:
            self._psis = [np.concatenate(self._psis, axis=0)]
        return self._psis[0] if self._psis else []

    def _drop_rows(self, n: int) -> None:
        if n and self._psis:
            self._psis = [self._psis[0][n:]]

    def _identity_to_state(self, i, ident: int) -> int:
        return int(ident)   # identities *are* states in the exact decoders

    def _ancestor_keep(self, f_state: int) -> np.ndarray:
        """(K,) bool: which frontier states trace back to ``f_state``."""
        anc = np.arange(self.K)
        for row in reversed(self._rows()):
            anc = row[anc]
        return anc == f_state

    def live_state_bytes(self) -> int:
        """Current live decoder state (the Fig. 11 memory metric)."""
        rows = self._rows()
        return len(rows) * self.K * 4 + self.K * 8


class OnlineViterbiDecoder(_ExactWindow):
    """Incremental exact Viterbi: feed (C, K) chunks, get committed prefixes.

        dec = OnlineViterbiDecoder(log_pi, log_A)
        for chunk in emission_stream:
            prefix = dec.feed(chunk)      # (n,) newly-final states, maybe empty
        tail, score = dec.flush()

    With ``max_lag=None`` (default) commits happen only at convergence points
    and the assembled path is exactly the offline Viterbi path.  With
    ``max_lag=L`` the uncommitted window never exceeds L steps (fixed-lag
    smoothing semantics — the forced part of the path is approximate).
    """

    def __init__(self, log_pi, log_A, *, max_lag: int | None = None,
                 bt: int = 8, constraint=None):
        super().__init__(max_lag)
        self.log_pi = jnp.asarray(log_pi)
        self.log_A = jnp.asarray(log_A)
        self.K = int(self.log_A.shape[0])
        self.bt = bt
        self.constraint = constraint
        if constraint is not None:
            # static components mask the model once; the per-step schedule is
            # added chunk-by-chunk in `feed` (same elementwise adds as the
            # offline `constrain_inputs`, so streaming stays bit-identical)
            from .constraints import init_penalty, transition_penalty
            pi_pen = init_penalty(constraint, self.K)
            t_pen = transition_penalty(constraint, self.K)
            if pi_pen is not None:
                self.log_pi = self.log_pi + jnp.asarray(pi_pen)
            if t_pen is not None:
                self.log_A = self.log_A + jnp.asarray(t_pen)
        self._delta: jax.Array | None = None

    # -- window plumbing ----------------------------------------------------
    def _frontier_best(self) -> tuple[int, float]:
        # flashlint: disable=FL002(commit point: one batched frontier transfer instead of two scalar syncs)
        delta = jax.device_get(self._delta)
        q = int(delta.argmax())
        return q, float(delta[q])

    def _mask_inconsistent(self, f_state: int) -> None:
        keep = jnp.asarray(self._ancestor_keep(f_state))
        # flashlint: disable=FL007(forced-commit suppression seam; accumulative add by design, not an allowed-set mask)
        self._delta = jnp.where(keep, self._delta, self._delta + 4.0 * NEG_INF)

    # -- feeding ------------------------------------------------------------
    def feed(self, em_chunk) -> np.ndarray:
        """Advance the DP by one emission chunk; returns newly committed states."""
        from repro.kernels.ops import viterbi_chunk_step
        em_chunk = jnp.asarray(em_chunk)
        self._check_open(em_chunk)
        if em_chunk.shape[0] == 0:
            return np.zeros((0,), np.int32)
        if self.constraint is not None:
            from .constraints import step_penalty_rows
            rows = step_penalty_rows(self.constraint, self.K, self._t,
                                     int(em_chunk.shape[0]))
            if rows is not None:
                em_chunk = em_chunk + jnp.asarray(rows)
        if self._delta is None:
            self._delta = self.log_pi + em_chunk[0]
            self._t = 1
            em_chunk = em_chunk[1:]
        if em_chunk.shape[0]:
            psi, self._delta = viterbi_chunk_step(
                self.log_A, em_chunk, self._delta, bt=self.bt)
            # flashlint: disable=FL002(window transfer: backpointers feed the host-side convergence scan)
            self._psis.append(np.asarray(psi))
            self._t += int(em_chunk.shape[0])
        return self._after_feed()


# ---------------------------------------------------------------------------
# Externally-advanced slot decoder (the inflight serving tier's per-slot view)
# ---------------------------------------------------------------------------

class SlotViterbiDecoder(_ExactWindow):
    """Exact commit machinery for a decode whose DP advance happens elsewhere.

    The inflight scheduler (`serving.inflight`) advances *all* of its slots
    with one batched kernel call per block; each slot then owns only the
    host-side window bookkeeping.  This class is that bookkeeping: the same
    convergence-commit / forced-flush algebra as `OnlineViterbiDecoder`
    (bit-identical, because the DP itself is the same per-step recurrence —
    the batched kernel is pinned bit-identical per sequence to the single-
    sequence kernel), minus any device state of its own.

    The two device touch-points are injected:

      frontier()      -> (K,) host array: this slot's current delta row.
                         Pulled only at flush / forced-flush time.
      mask_scores(keep (K,) bool) -> None: suppress frontier hypotheses whose
                         ancestor is inconsistent with a forced commit
                         (the scheduler applies it to its batched delta).

    Lifecycle: ``seed()`` once the first frame's delta row has been placed
    (t becomes 1), then ``ingest(psi_rows)`` after every externally-computed
    block advance; ``flush()`` (inherited) finishes.  ``save_state()`` /
    ``restore_state()`` round-trip the full host-side window so a slot can be
    checkpointed or migrated without replaying the stream.
    """

    def __init__(self, K: int, *, max_lag: int | None = None,
                 frontier=None, mask_scores=None):
        super().__init__(max_lag)
        self.K = int(K)
        if frontier is None:
            raise ValueError("SlotViterbiDecoder needs a frontier() callback")
        self._frontier = frontier
        self._mask_scores = mask_scores

    # -- external-advance surface -------------------------------------------
    def seed(self) -> None:
        """Mark the slot live: the caller just placed delta_0 for frame 0."""
        if self._finished:
            raise RuntimeError("slot decoder already flushed")
        if self._t:
            raise RuntimeError("slot decoder already seeded")
        self._t = 1

    def ingest(self, psi_rows: np.ndarray) -> np.ndarray:
        """Append externally-computed backpointer rows; commit what is final.

        ``psi_rows`` is (n, K) int32 mapping states at the n newly-fed steps
        to their predecessors (exactly `viterbi_chunk_step`'s psi output for
        this slot).  Returns the newly-committed states, like ``feed``.
        """
        if self._finished:
            raise RuntimeError("slot decoder already flushed")
        if self._t == 0:
            raise RuntimeError("slot decoder not seeded; call seed() first")
        # flashlint: disable=FL002(psi rows are already host numpy — the scheduler batched the transfer)
        psi_rows = np.asarray(psi_rows, np.int32)
        if psi_rows.ndim != 2 or psi_rows.shape[1] != self.K:
            raise ValueError(f"expected (n, K={self.K}) psi rows, "
                             f"got {psi_rows.shape}")
        if psi_rows.shape[0] == 0:
            return np.zeros((0,), np.int32)
        self._psis.append(psi_rows)
        self._t += int(psi_rows.shape[0])
        return self._after_feed()

    # -- _StreamingDecoder surface ------------------------------------------
    def _frontier_best(self) -> tuple[int, float]:
        # flashlint: disable=FL002(commit point: the injected frontier callback is the one batched row transfer)
        row = np.asarray(self._frontier())
        q = int(row.argmax())
        return q, float(row[q])

    def _mask_inconsistent(self, f_state: int) -> None:
        if self._mask_scores is None:
            raise RuntimeError(
                "forced flush needs a mask_scores callback (max_lag is set "
                "but the scheduler did not wire score masking)")
        self._mask_scores(self._ancestor_keep(f_state))

    # -- checkpoint / migration ---------------------------------------------
    def save_state(self) -> dict:
        """Host-side window snapshot (the device delta row is the caller's)."""
        return {"committed": list(self._committed), "t": self._t,
                "base": self._base, "finished": self._finished,
                "score": self.score, "stats": dict(self.stats),
                "psis": [p.copy() for p in self._psis]}

    def restore_state(self, state: dict) -> None:
        self._committed = list(state["committed"])
        self._t = int(state["t"])
        self._base = int(state["base"])
        self._finished = bool(state["finished"])
        self.score = state["score"]
        self.stats = dict(state["stats"])
        # flashlint: disable=FL002(restoring a host-side snapshot, no device data involved)
        self._psis = [np.asarray(p, np.int32).copy() for p in state["psis"]]


# ---------------------------------------------------------------------------
# Streaming dynamic-beam decoder
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("B", "kchunk"))
def _beam_init(log_pi, em0, B: int, kchunk: int):
    v = log_pi + em0
    return _stream_top_b(
        lambda c: jax.lax.dynamic_slice(v, (c * kchunk,), (kchunk,)),
        v.shape[0], kchunk, B)


@partial(jax.jit, static_argnames=("B", "kchunk"))
def _beam_chunk_scan(log_A, em_chunk, scores, states, B: int, kchunk: int):
    def step(carry, em_t):
        sc, st = carry
        ns, nst, nfrom = _beam_transition(log_A, em_t, sc, st, kchunk, B)
        return (ns, nst), (nst, nfrom)

    (sc, st), (sts, froms) = jax.lax.scan(step, (scores, states), em_chunk)
    return sc, st, sts, froms


class OnlineBeamDecoder(_StreamingDecoder):
    """Streaming FLASH-BS: O(B) beam carry + O(W * B) window, K never live.

    The convergence check runs over *beam-slot* backpointers: once every slot
    of the current beam traces back to the same past slot, that slot's state
    is committed.  With ``beam_width >= K`` this is exact decoding (ties
    aside); narrower beams inherit FLASH-BS's accuracy/memory trade-off
    (paper Fig. 9) with streaming latency on top.
    """

    def __init__(self, log_pi, log_A, *, beam_width: int = 128,
                 kchunk: int = 128, max_lag: int | None = None,
                 constraint=None):
        super().__init__(max_lag)
        log_pi = jnp.asarray(log_pi)
        log_A = jnp.asarray(log_A)
        K = int(log_A.shape[0])
        self.K = K
        self.B = int(min(beam_width, K))
        self.constraint = constraint
        if constraint is not None:
            # mask before the sentinel padding below: the intersection of the
            # beam with the allowed set falls out of the top-B itself —
            # disallowed states score ~NEG_INF and lose every slot, so the
            # constraint compounds with the beam pruning for free
            from .constraints import init_penalty, transition_penalty
            pi_pen = init_penalty(constraint, K)
            t_pen = transition_penalty(constraint, K)
            if pi_pen is not None:
                log_pi = log_pi + jnp.asarray(pi_pen)
            if t_pen is not None:
                log_A = log_A + jnp.asarray(t_pen)
        kchunk = int(min(kchunk, K))
        # pad K to a kchunk multiple; fake states get sentinel scores so they
        # never displace real candidates (same scheme as flash_bs_viterbi)
        K_pad = -(-K // kchunk) * kchunk
        if K_pad != K:
            log_A = jnp.pad(log_A, ((0, K_pad - K), (0, K_pad - K)),
                            constant_values=_SENTINEL / 2)
            log_pi = jnp.pad(log_pi, (0, K_pad - K),
                             constant_values=_SENTINEL / 2)
        self.K_pad = K_pad
        self.kchunk = kchunk
        self.log_pi = log_pi
        self.log_A = log_A
        self._scores: jax.Array | None = None
        self._states: jax.Array | None = None
        self._froms: list[np.ndarray] = []    # row i: slots(base+i)->slots(base+i-1)
        self._sstates: list[np.ndarray] = []  # entry j: slot states at time base-1+j

    # -- window plumbing ----------------------------------------------------
    def _rows(self) -> list[np.ndarray]:
        return self._froms

    def _drop_rows(self, n: int) -> None:
        if n:
            self._froms = self._froms[n:]
            self._sstates = self._sstates[n:]

    def _frontier_best(self) -> tuple[int, float]:
        # flashlint: disable=FL002(commit point: one batched frontier transfer instead of two scalar syncs)
        scores = jax.device_get(self._scores)
        b = int(scores.argmax())
        return b, float(scores[b])

    def _identity_to_state(self, i, slot: int) -> int:
        # flashlint: disable=FL002(window rows are host numpy already, no device sync)
        return int(self._sstates[i][slot])

    def _mask_inconsistent(self, f_state: int) -> None:
        rows = self._rows()
        anc = np.arange(self.B)
        for i in range(len(rows) - 1, -1, -1):
            anc = rows[i][anc]
        keep = jnp.asarray(self._sstates[0][anc] == f_state)
        # flashlint: disable=FL007(beam forced-commit suppression seam, same accumulative add as the dense decoder)
        self._scores = jnp.where(keep, self._scores,
                                 self._scores + 4.0 * NEG_INF)

    # -- feeding ------------------------------------------------------------
    def feed(self, em_chunk) -> np.ndarray:
        """Advance the beam by one emission chunk; returns committed states."""
        em_chunk = jnp.asarray(em_chunk)
        self._check_open(em_chunk)
        if em_chunk.shape[0] == 0:
            return np.zeros((0,), np.int32)
        if self.constraint is not None and em_chunk.shape[1] == self.K:
            from .constraints import step_penalty_rows
            rows = step_penalty_rows(self.constraint, self.K, self._t,
                                     int(em_chunk.shape[0]))
            if rows is not None:
                em_chunk = em_chunk + jnp.asarray(rows)
        if self.K_pad != self.K and em_chunk.shape[1] == self.K:
            em_chunk = jnp.pad(em_chunk, ((0, 0), (0, self.K_pad - self.K)),
                               constant_values=_SENTINEL / 2)
        if self._scores is None:
            self._scores, self._states = _beam_init(
                self.log_pi, em_chunk[0], self.B, self.kchunk)
            # flashlint: disable=FL002(window transfer: slot states feed the host-side convergence scan)
            self._sstates.append(np.asarray(self._states))
            self._t = 1
            em_chunk = em_chunk[1:]
        if em_chunk.shape[0]:
            self._scores, self._states, sts, froms = _beam_chunk_scan(
                self.log_A, em_chunk, self._scores, self._states,
                self.B, self.kchunk)
            # flashlint: disable=FL002(window transfer: slot pointers feed the host-side convergence scan)
            sts, froms = np.asarray(sts), np.asarray(froms)
            for r in range(sts.shape[0]):
                self._sstates.append(sts[r])
                self._froms.append(froms[r])
            self._t += int(em_chunk.shape[0])
        return self._after_feed()

    def live_state_bytes(self) -> int:
        """Current live decoder state: O(W * B), decoupled from K."""
        return len(self._froms) * self.B * 8 + self.B * 8


# ---------------------------------------------------------------------------
# One-shot wrappers (offline signature over the streaming engine)
# ---------------------------------------------------------------------------

def viterbi_online(log_pi, log_A, em, *, chunk_size: int = 64,
                   max_lag: int | None = None, bt: int = 8):
    """Decode (T, K) emissions by streaming them chunk-by-chunk.

    Equivalent to ``viterbi_vanilla`` output-wise (bit-identical when
    ``max_lag=None``); exists so the online path slots into ``viterbi_decode``
    and the benchmarks.  Returns (path (T,) int32, score).
    """
    dec = OnlineViterbiDecoder(log_pi, log_A, max_lag=max_lag, bt=bt)
    T = em.shape[0]
    for s in range(0, T, chunk_size):
        dec.feed(em[s:s + chunk_size])
    _, score = dec.flush()
    return jnp.asarray(dec.path), jnp.asarray(score, dtype=jnp.float32)


def viterbi_online_beam(log_pi, log_A, em, *, beam_width: int = 128,
                        chunk_size: int = 64, kchunk: int = 128,
                        max_lag: int | None = None):
    """Streaming beam decode of (T, K) emissions; returns (path, score)."""
    dec = OnlineBeamDecoder(log_pi, log_A, beam_width=beam_width,
                            kchunk=kchunk, max_lag=max_lag)
    T = em.shape[0]
    for s in range(0, T, chunk_size):
        dec.feed(em[s:s + chunk_size])
    _, score = dec.flush()
    return jnp.asarray(dec.path), jnp.asarray(score, dtype=jnp.float32)


__all__ = ["OnlineViterbiDecoder", "OnlineBeamDecoder", "SlotViterbiDecoder",
           "viterbi_online", "viterbi_online_beam"]
