"""Pure-numpy reference decoders.

Two roles:
  1. Oracles for every JAX/Pallas implementation in the test-suite (including an
     exhaustive brute-force search for tiny problems).
  2. The "interpreted baseline" column of the Table-I analogue benchmark — the
     paper reports Python vs C implementations; our analogue is numpy (interpreted,
     per-op dispatch) vs jitted XLA (compiled).
"""

from __future__ import annotations

# flashlint: disable-file=FL002(pure-numpy oracle: everything here is host-side by design)

import itertools

import numpy as np

NEG_INF = -1.0e9


def viterbi_numpy(log_pi: np.ndarray, log_A: np.ndarray, em: np.ndarray):
    """Vanilla Viterbi, O(KT) space. Returns (path (T,), score)."""
    T, K = em.shape
    delta = log_pi + em[0]
    psi = np.zeros((T, K), dtype=np.int64)
    for t in range(1, T):
        scores = delta[:, None] + log_A  # (K, K): src x dst
        psi[t] = np.argmax(scores, axis=0)
        delta = scores[psi[t], np.arange(K)] + em[t]
    path = np.zeros((T,), dtype=np.int64)
    path[-1] = int(np.argmax(delta))
    for t in range(T - 2, -1, -1):
        path[t] = psi[t + 1][path[t + 1]]
    return path, float(np.max(delta))


def checkpoint_viterbi_numpy(log_pi: np.ndarray, log_A: np.ndarray, em: np.ndarray):
    """Checkpoint Viterbi [Tarnas & Hughey 98]: O(K sqrt(T)) space."""
    T, K = em.shape
    c = max(1, int(np.ceil(np.sqrt(T))))
    # forward: store delta at checkpoint starts
    starts = list(range(0, T, c))
    saved = {}
    delta = log_pi + em[0]
    saved[0] = delta.copy()
    for t in range(1, T):
        delta = np.max(delta[:, None] + log_A, axis=0) + em[t]
        if t in starts:
            saved[t] = delta.copy()
    path = np.zeros((T,), dtype=np.int64)
    path[-1] = int(np.argmax(delta))
    score = float(np.max(delta))
    # backward: re-run each segment to recover its psi table, then backtrack
    for s in reversed(starts):
        e = min(s + c, T) - 1  # inclusive segment end; path[e] known (or e == T-1)
        d = saved[s].copy()
        psis = np.zeros((e - s + 1, K), dtype=np.int64)
        for t in range(s + 1, e + 1):
            scores = d[:, None] + log_A
            psis[t - s] = np.argmax(scores, axis=0)
            d = scores[psis[t - s], np.arange(K)] + em[t]
        for t in range(e - 1, s - 1, -1):
            path[t] = psis[t - s + 1][path[t + 1]]
    return path, score


def brute_force(log_pi: np.ndarray, log_A: np.ndarray, em: np.ndarray):
    """Exhaustive search over all K^T paths. Tiny problems only."""
    T, K = em.shape
    best, best_path = -np.inf, None
    for path in itertools.product(range(K), repeat=T):
        s = log_pi[path[0]] + em[0, path[0]]
        for t in range(1, T):
            s += log_A[path[t - 1], path[t]] + em[t, path[t]]
        if s > best:
            best, best_path = s, path
    return np.asarray(best_path, dtype=np.int64), float(best)


def path_score_numpy(log_pi, log_A, em, path) -> float:
    s = log_pi[path[0]] + em[0, path[0]]
    for t in range(1, len(path)):
        s += log_A[path[t - 1], path[t]] + em[t, path[t]]
    return float(s)


def sieve_mp_numpy(log_pi: np.ndarray, log_A: np.ndarray, em: np.ndarray):
    """SIEVE-MiddlePath [Ciaperoni+ 22]: recursive sequence-halving D&C, O(K) space.

    The paper's strongest space-efficient baseline.  Faithfully *recursive* (this is
    exactly the structural cost FLASH removes); each call runs DP over its segment
    tracking only the mid-point backpointer.

    Unlike FLASH, SIEVE-Mp does NOT prune: the right child is seeded with the full
    delta K-vector captured at the parent's midpoint (this cross-subtask K-vector
    dependency is exactly what FLASH's pruning removes to unlock parallelism).
    """
    T, K = em.shape
    path = np.zeros((T,), dtype=np.int64)

    def segment_dp(m, n, entry_delta):
        """DP over [m, n].

        Returns (delta_n, mid, delta_mid) where mid[j] is the state at tmid of the
        best path reaching state j at n, and delta_mid is the delta vector at tmid
        (handed to the right child, SIEVE-Mp style).
        """
        tmid = (m + n) // 2
        if entry_delta is None:  # m == 0
            delta = log_pi + em[0]
        else:
            delta = np.max(entry_delta[:, None] + log_A, axis=0) + em[m]
        mid = np.zeros((K,), dtype=np.int64)
        delta_mid = delta.copy() if tmid == m else None
        for t in range(m + 1, n + 1):
            scores = delta[:, None] + log_A
            psi = np.argmax(scores, axis=0)
            delta = scores[psi, np.arange(K)] + em[t]
            if t == tmid:
                delta_mid = delta.copy()
            if t == tmid + 1:
                mid = psi.copy()
            elif t > tmid + 1:
                mid = mid[psi]
        return delta, mid, delta_mid

    score_box = [None]

    def solve(m, n, entry_delta, exit_state):
        if n <= m:
            return
        tmid = (m + n) // 2
        delta, mid, delta_mid = segment_dp(m, n, entry_delta)
        if exit_state is None:  # top-level call: pin the global final state
            exit_state = int(np.argmax(delta))
            path[n] = exit_state
            score_box[0] = float(np.max(delta))
        q_mid = int(mid[exit_state])
        path[tmid] = q_mid
        if n == m + 1:  # tmid == m: segment fully resolved
            return
        solve(m, tmid, entry_delta, q_mid)       # left half: exit pinned at tmid
        solve(tmid + 1, n, delta_mid, exit_state)  # right half: full K-vector seed

    if T == 1:
        path[0] = int(np.argmax(log_pi + em[0]))
        return path, float(np.max(log_pi + em[0]))
    solve(0, T - 1, None, None)
    return path, float(score_box[0])


__all__ = [
    "viterbi_numpy",
    "checkpoint_viterbi_numpy",
    "brute_force",
    "path_score_numpy",
    "sieve_mp_numpy",
]
