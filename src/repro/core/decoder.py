"""`ViterbiDecoder` — one resource-adaptive decoder object for every call site.

Binds a typed `DecodeSpec` to an HMM (log_pi, log_A) and exposes the three
execution shapes the system serves through, uniformly:

    dec = ViterbiDecoder(FlashSpec(parallelism=8), log_pi, log_A)
    path,  score  = dec.decode(em)                      # one (T, K) sequence
    paths, scores = dec.decode_batch(ems, lengths=ln)   # ragged (B, T, K)
    paths, scores = dec.decode_sharded(ems, lengths=ln, mesh=mesh)

Compilation is cached per (spec, shape-bucket) in *module-level* jit tables
keyed by the spec itself (specs are frozen and hashable precisely so they can
be cache keys): two `ViterbiDecoder`s built from equal specs — e.g. one per
serving head — share a single compilation, with the HMM tensors passed as
traced arguments.  jit's own cache then keys on shapes, one compile per
length bucket; `analysis/retrace.py` fails CI if an equal spec or a ragged
batch within one bucket ever retraces.  The sharded path reuses
`core.batch`'s per-(mesh, method, tunables) compiled-decoder cache.  The
streaming specs (`OnlineSpec`/`OnlineBeamSpec`) are stateful Python loops, so
they run eagerly and reject the batched entry points.

Results are bit-identical to the legacy `viterbi_decode(method=..., **kw)`
shim built from the same tunables — both run the same `spec.run`;
`tests/test_api.py` pins this for every method.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .spec import DecodeSpec, as_decode_spec

__all__ = ["ViterbiDecoder"]


def _run_spec(spec: DecodeSpec, log_pi, log_A, em):
    return spec.run(log_pi, log_A, em)


def _run_spec_batch(spec: DecodeSpec, em, log_pi, log_A, lengths):
    from .batch import viterbi_decode_batch
    return viterbi_decode_batch(em, log_pi, log_A, lengths,
                                method=spec.batch_method,
                                constraint=spec.constraint,
                                **spec.batch_tunables())


@functools.lru_cache(maxsize=None)
def _jit_decode(spec: DecodeSpec):
    """Shared single-sequence jit entry for `spec` (spec is the cache key)."""
    return jax.jit(functools.partial(_run_spec, spec))


@functools.lru_cache(maxsize=None)
def _jit_decode_batch(spec: DecodeSpec):
    """Shared ragged-batch jit entry for `spec`."""
    return jax.jit(functools.partial(_run_spec_batch, spec))


class ViterbiDecoder:
    """A `DecodeSpec` bound to one HMM, with jit-compile caching."""

    def __init__(self, spec: DecodeSpec, log_pi, log_A):
        self.spec = as_decode_spec(spec)
        self.log_pi = jnp.asarray(log_pi)
        self.log_A = jnp.asarray(log_A)

    def __repr__(self):
        return (f"ViterbiDecoder({self.spec!r}, "
                f"K={int(self.log_A.shape[0])})")

    # -- single sequence ----------------------------------------------------
    def decode(self, emissions) -> tuple[jax.Array, jax.Array]:
        """Decode one (T, K) sequence -> (path (T,) int32, score)."""
        em = jnp.asarray(emissions)
        if self.spec.jittable:
            return _jit_decode(self.spec)(self.log_pi, self.log_A, em)
        return self.spec.run(self.log_pi, self.log_A, em)

    # -- ragged batch -------------------------------------------------------
    def _require_batchable(self, entry: str) -> str:
        if self.spec.batch_method is None:
            raise ValueError(
                f"{type(self.spec).__name__} has no batched path; {entry} "
                f"needs a spec whose method is in core.batch.BATCH_METHODS")
        return self.spec.batch_method

    def _lengths(self, emissions, lengths) -> jax.Array:
        from .batch import _validate_lengths
        B, T = emissions.shape[0], emissions.shape[1]
        if lengths is None:
            return jnp.full((B,), T, jnp.int32)
        lengths = jnp.asarray(lengths, jnp.int32)
        _validate_lengths(lengths, T)   # eager, before entering jit
        return lengths

    def decode_batch(self, emissions, lengths=None
                     ) -> tuple[jax.Array, jax.Array]:
        """Decode a (B, T, K) batch; `lengths` (B,) makes rows ragged.

        Inherits the `viterbi_decode_batch` contract: pad frames run as
        tropical-identity steps, so `paths[i, :lengths[i]]` is bit-identical
        to `decode(emissions[i, :lengths[i]])` for exact methods.
        """
        self._require_batchable("decode_batch")
        emissions = jnp.asarray(emissions)
        lengths = self._lengths(emissions, lengths)
        return _jit_decode_batch(self.spec)(emissions, self.log_pi,
                                            self.log_A, lengths)

    # -- mesh-sharded batch -------------------------------------------------
    def decode_sharded(self, emissions, lengths=None, *, mesh,
                       data_axis: str = "data"
                       ) -> tuple[jax.Array, jax.Array]:
        """Decode a (B, T, K) batch sharded over `mesh`'s `data_axis`.

        Buckets whose size does not divide the axis are padded up with
        length-1 dummy rows and sliced back (sequences are independent, so
        dummies change nothing).  Per-sequence results stay bit-identical to
        `decode_batch` — the shard body is the same per-device decode.
        """
        method = self._require_batchable("decode_sharded")
        from .batch import viterbi_decode_batch
        emissions = jnp.asarray(emissions)
        B = emissions.shape[0]
        lengths = self._lengths(emissions, lengths)
        pad_b = -B % mesh.shape[data_axis]
        if pad_b:
            emissions = jnp.concatenate(
                [emissions,
                 jnp.zeros((pad_b,) + emissions.shape[1:], emissions.dtype)])
            lengths = jnp.concatenate(
                [lengths, jnp.ones((pad_b,), jnp.int32)])
        paths, scores = viterbi_decode_batch(
            emissions, self.log_pi, self.log_A, lengths, method=method,
            mesh=mesh, data_axis=data_axis, constraint=self.spec.constraint,
            **self.spec.batch_tunables())
        return paths[:B], scores[:B]

    # -- streaming ----------------------------------------------------------
    def make_streaming(self):
        """Stateful incremental decoder for the streaming specs."""
        mk = getattr(self.spec, "make_streaming", None)
        if mk is None:
            raise ValueError(
                f"{type(self.spec).__name__} is not a streaming spec; use "
                f"OnlineSpec / OnlineBeamSpec")
        return mk(self.log_pi, self.log_A)
