"""Static beam search baselines (SIEVE-BS / SIEVE-BS-Mp analogues, paper Sec. II-B).

Static beam search scores *all* K successor states at each step and only then
truncates to the top-B — so its transient memory stays O(K) even though only B
paths survive (the paper's core criticism, Sec. V-C-1).  We provide:

  * `beam_static_viterbi`   — full-table variant: (T, B) survivor/backpointer
                              tables, backtracked at the end (SIEVE-BS analogue).
  * `beam_static_mp_viterbi`— divide-and-conquer variant reusing the FLASH
                              wavefront but with the static per-step truncation
                              (SIEVE-BS-Mp analogue).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import flash_bs as _fbs


@partial(jax.jit, static_argnames=("B",))
def beam_static_viterbi(log_pi, log_A, em, B: int):
    """Static beam search with full survivor tables. Returns (path, score)."""
    T, K = em.shape

    s0 = log_pi + em[0]
    scores0, states0 = jax.lax.top_k(s0, B)

    def step(carry, em_t):
        scores, states = carry
        # static: materialise the full (B, K) candidate block, then truncate
        cand = scores[:, None] + log_A[states] + em_t[None, :]   # (B, K)
        from_b = jnp.argmax(cand, axis=0).astype(jnp.int32)      # (K,)
        best = jnp.max(cand, axis=0)                             # (K,)
        new_scores, new_states = jax.lax.top_k(best, B)
        new_states = new_states.astype(jnp.int32)
        return (new_scores, new_states), (new_states, from_b[new_states])

    (scores, _), (surv_states, surv_from) = jax.lax.scan(
        step, (scores0, states0.astype(jnp.int32)), em[1:])

    b_best = jnp.argmax(scores)
    score = scores[b_best]

    # backtrack through the survivor tables: surv_from[t, b] is the beam slot at
    # t-1 feeding survivor b at t
    def back(slot, tables):
        st, frm = tables
        return frm[slot], st[slot]

    last_slot = b_best.astype(jnp.int32)
    q_last = surv_states[-1, b_best]
    first_slot, path_tail = jax.lax.scan(
        back, last_slot, (surv_states, surv_from), reverse=True)
    # path_tail[t] is the state at step t+1; prepend step 0
    q0 = states0.astype(jnp.int32)[first_slot]
    path = jnp.concatenate([q0[None], path_tail])
    return path, score


def beam_static_mp_viterbi(log_pi, log_A, em, beam_width: int = 128,
                           parallelism: int = 8, lanes: int | None = -1):
    """D&C static beam search: FLASH wavefront, but each step materialises K.

    Implemented as FLASH-BS with chunk == K (a single chunk = full
    materialisation) — the precise formal difference between static and dynamic
    beam search in this codebase.
    """
    K = em.shape[1]
    return _fbs.flash_bs_viterbi(
        log_pi, log_A, em, beam_width=beam_width, parallelism=parallelism,
        lanes=lanes, chunk=K)


__all__ = ["beam_static_viterbi", "beam_static_mp_viterbi"]
