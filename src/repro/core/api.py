"""Unified Viterbi operator — the public entry point used by serving, examples
and benchmarks.

    path, score = viterbi_decode(emissions, log_pi, log_A, method="flash", ...)

`method` selects among the paper's algorithm ("flash", "flash_bs"), the paper's
baselines ("vanilla", "checkpoint", "beam_static", "beam_static_mp"), the
beyond-paper associative-scan schedule ("assoc"), the fused Pallas forward
kernel ("fused"), and the streaming decoders ("online", "online_beam" —
chunk-fed one-shot; for true incremental use, hold an `OnlineViterbiDecoder` /
`serving.stream.StreamSession` directly).  Tunables `parallelism`, `lanes`,
`beam_width` and `chunk` realise the paper's adaptivity story: one operator,
resource profile chosen per deployment.

Batches go through `viterbi_decode_batch(emissions (B, T, K), log_pi, log_A,
lengths)` — ragged lengths decode exactly via tropical-identity pad steps; see
`core/batch.py`.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from .hmm import HMM
from .vanilla import viterbi_vanilla
from .checkpoint_viterbi import viterbi_checkpoint
from .flash import flash_viterbi
from .flash_bs import flash_bs_viterbi
from .beam_static import beam_static_viterbi, beam_static_mp_viterbi
from .assoc import viterbi_assoc
from .online import viterbi_online, viterbi_online_beam
from .batch import viterbi_decode_batch, BATCH_METHODS

METHODS = ("vanilla", "checkpoint", "flash", "flash_bs",
           "beam_static", "beam_static_mp", "assoc", "fused",
           "online", "online_beam")


def viterbi_decode(
    emissions: jax.Array,
    log_pi: jax.Array,
    log_A: jax.Array,
    method: str = "flash",
    *,
    parallelism: int = 8,
    lanes: int | None = -1,
    beam_width: int = 128,
    chunk: int = 128,
    seg_len: int | None = None,
    stream_chunk: int = 64,
    max_lag: int | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Decode the max-likelihood state path of (T, K) emissions.

    Returns (path (T,) int32, score). See module docstring for `method`.
    """
    if method == "vanilla":
        return viterbi_vanilla(log_pi, log_A, emissions)
    if method == "checkpoint":
        return viterbi_checkpoint(log_pi, log_A, emissions, seg_len=seg_len)
    if method == "flash":
        return flash_viterbi(log_pi, log_A, emissions,
                             parallelism=parallelism, lanes=lanes)
    if method == "flash_bs":
        return flash_bs_viterbi(log_pi, log_A, emissions, beam_width=beam_width,
                                parallelism=parallelism, lanes=lanes, chunk=chunk)
    if method == "beam_static":
        return beam_static_viterbi(log_pi, log_A, emissions,
                                   B=min(beam_width, emissions.shape[1]))
    if method == "beam_static_mp":
        return beam_static_mp_viterbi(log_pi, log_A, emissions,
                                      beam_width=beam_width,
                                      parallelism=parallelism, lanes=lanes)
    if method == "assoc":
        return viterbi_assoc(log_pi, log_A, emissions)
    if method == "fused":
        from repro.kernels.ops import viterbi_decode_fused
        return viterbi_decode_fused(log_pi, log_A, emissions)
    if method == "online":
        return viterbi_online(log_pi, log_A, emissions,
                              chunk_size=stream_chunk, max_lag=max_lag)
    if method == "online_beam":
        return viterbi_online_beam(log_pi, log_A, emissions,
                                   beam_width=beam_width, kchunk=chunk,
                                   chunk_size=stream_chunk, max_lag=max_lag)
    raise ValueError(f"unknown method {method!r}; choose from {METHODS}")


def viterbi_decode_hmm(obs: jax.Array, hmm: HMM, method: str = "flash",
                       **kwargs: Any) -> tuple[jax.Array, jax.Array]:
    """Decode discrete observations under an `HMM` container."""
    return viterbi_decode(hmm.emissions(obs), hmm.log_pi, hmm.log_A,
                          method=method, **kwargs)


__all__ = ["viterbi_decode", "viterbi_decode_hmm", "viterbi_decode_batch",
           "METHODS", "BATCH_METHODS"]
