"""Unified Viterbi operator — the public entry point used by serving, examples
and benchmarks.

The typed API (preferred):

    from repro.core import FlashSpec, ViterbiDecoder, plan, ResourceBudget

    spec = FlashSpec(parallelism=8)                      # typed + validated
    spec = plan(K, T, ResourceBudget(memory_bytes=64 << 10)).spec  # or planned
    dec = ViterbiDecoder(spec, log_pi, log_A)            # jit-cached per spec
    path, score = dec.decode(emissions)                  # (T, K)
    paths, scores = dec.decode_batch(ems, lengths=ln)    # ragged (B, T, K)
    paths, scores = dec.decode_sharded(ems, mesh=mesh)   # mesh data-parallel

Specs (`core/spec.py`) are frozen, hashable per-method dataclasses with eager
validation — nonsense like ``beam_width=0`` raises at construction, and a
tunable the method does not consume cannot even be expressed.  The planner
(`core/planner.py`) turns a `ResourceBudget` into a spec via the paper's
Sec. V-C-3 degradation ladder (exact+parallel -> shrink P -> beam -> floor).

The legacy string+kwargs form is kept as a thin shim over the same specs:

    path, score = viterbi_decode(emissions, log_pi, log_A, method="flash", ...)

It is pinned bit-identical to the spec path by `tests/test_api.py`.  One
behavioral change: passing a tunable the method ignores (e.g. ``beam_width``
with ``method="vanilla"``) now emits a `DeprecationWarning` instead of being
silently dropped.

Batches go through `viterbi_decode_batch(emissions (B, T, K), log_pi, log_A,
lengths)` — ragged lengths decode exactly via tropical-identity pad steps; see
`core/batch.py`.
"""

from __future__ import annotations

import warnings
from typing import Any

import jax

from .hmm import HMM
from .spec import spec_from_tunables, SPEC_BY_METHOD
from .batch import viterbi_decode_batch, BATCH_METHODS

METHODS = tuple(SPEC_BY_METHOD)

_UNSET: Any = object()


def viterbi_decode(
    emissions: jax.Array,
    log_pi: jax.Array,
    log_A: jax.Array,
    method: str = "flash",
    *,
    parallelism: int = _UNSET,
    lanes: int | None = _UNSET,
    beam_width: int = _UNSET,
    chunk: int = _UNSET,
    seg_len: int | None = _UNSET,
    stream_chunk: int = _UNSET,
    max_lag: int | None = _UNSET,
    bt: int = _UNSET,
    constraint: Any = _UNSET,
) -> tuple[jax.Array, jax.Array]:
    """Decode the max-likelihood state path of (T, K) emissions.

    Back-compat shim: builds the typed spec for `method` and runs it, so the
    result is bit-identical to `ViterbiDecoder(spec, log_pi, log_A).decode`.
    Returns (path (T,) int32, score).  Tunables the method does not consume
    raise a DeprecationWarning (they used to be silently ignored).

    Constrained decoding is typed-API only: `constraint=` here raises
    `TypeError` rather than joining the warn-and-ignore policy — dropping a
    constraint silently would return paths the caller asked to forbid.
    """
    if constraint is not _UNSET:
        raise TypeError(
            "viterbi_decode() does not take constraint=; build a typed spec "
            "(e.g. FusedSpec(constraint=...)) and use ViterbiDecoder or "
            "spec.run — the legacy shim will not risk silently decoding "
            "unconstrained")
    passed = {name: value for name, value in (
        ("parallelism", parallelism), ("lanes", lanes),
        ("beam_width", beam_width), ("chunk", chunk), ("seg_len", seg_len),
        ("stream_chunk", stream_chunk), ("max_lag", max_lag), ("bt", bt),
    ) if value is not _UNSET}
    spec, ignored = spec_from_tunables(method, passed)
    if ignored:
        warnings.warn(
            f"viterbi_decode(method={method!r}) ignores tunable(s) "
            f"{', '.join(sorted(ignored))}; construct a "
            f"{type(spec).__name__} to get eager validation instead",
            DeprecationWarning, stacklevel=2)
    return spec.run(log_pi, log_A, emissions)


def viterbi_decode_hmm(obs: jax.Array, hmm: HMM, method: str = "flash",
                       **kwargs: Any) -> tuple[jax.Array, jax.Array]:
    """Decode discrete observations under an `HMM` container."""
    return viterbi_decode(hmm.emissions(obs), hmm.log_pi, hmm.log_A,
                          method=method, **kwargs)


__all__ = ["viterbi_decode", "viterbi_decode_hmm", "viterbi_decode_batch",
           "METHODS", "BATCH_METHODS"]
