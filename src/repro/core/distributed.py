"""Distributed FLASH Viterbi — the paper's parallelism mapped onto a TPU mesh.

Two orthogonal axes, composable on the production (data, model) mesh:

  * **Subtask parallelism over the `data` axis** — the paper's P threads.  Each
    wavefront layer's tiles are sharded across the data axis with `shard_map`;
    pruning (Sec. V-B) guarantees tiles are data-independent, so no collective
    is needed *within* a layer — only the pinned boundary states (a few int32s)
    are exchanged between layers.  This is the paper's claim "pruning removes
    inter-subtask dependencies to enable parallel decoding" made literal: the
    compiled HLO for a layer contains zero cross-tile communication.

  * **State parallelism over the `model` axis (tropical tensor parallelism)** —
    beyond the paper's thread model.  The DP step
        delta'[j] = max_k (delta[k] + log_A[k, j]) + em[j]
    is a (max,+) mat-vec: shard log_A by *source rows* across the model axis,
    compute each shard's partial max over its K/mp rows, and combine with an
    all-reduce-MAX (`lax.pmax`) — the exact tropical analogue of megatron-style
    row-parallel matmul + psum.  Backpointers combine with a second pmax over
    (value-matched) global row indices; ties resolve to the largest index
    (single-device argmax resolves to the smallest — path *scores* are
    invariant, asserted in tests).

Per-step collective cost on the model axis: 2 x all-reduce of K floats/ints —
this is what the roofline harness measures for the alignment-serving cell.

A third axis, **sequence parallelism over `data`** (`make_batched_flash_decoder`),
is the serving configuration: whole sequences shard across devices and decode
through `core.batch.viterbi_decode_batch`, inheriting its ragged-`lengths`
contract (pad frames are tropical-identity steps — no pad mass in scores).

All `shard_map` use goes through `runtime.jaxcompat`, which bridges the
jax 0.4.x / current-jax API drift (shard_map location, check_rep/check_vma);
this module must keep importing and running on both.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..runtime.jaxcompat import shard_map
from .hmm import NEG_INF
from .flash import plan_padding, pad_emissions


# ---------------------------------------------------------------------------
# Tropical tensor-parallel DP step (model axis)
# ---------------------------------------------------------------------------

def _tp_dp_step(delta, log_A_local, em_t, is_pad, axis: str):
    """One row-sharded Viterbi step inside shard_map.

    delta: (K,) replicated; log_A_local: (K/mp, K) this shard's source rows;
    returns (delta', psi') both (K,) replicated (combined via pmax).
    """
    K = delta.shape[0]
    kl = log_A_local.shape[0]
    shard = jax.lax.axis_index(axis)
    row0 = shard * kl
    delta_local = jax.lax.dynamic_slice(delta, (row0,), (kl,))

    scores = delta_local[:, None] + log_A_local          # (kl, K)
    part_val = jnp.max(scores, axis=0)                   # (K,)
    part_arg = jnp.argmax(scores, axis=0).astype(jnp.int32) + row0

    vmax = jax.lax.pmax(part_val, axis)                  # all-reduce-MAX
    contrib = jnp.where(part_val >= vmax, part_arg, jnp.int32(-1))
    psi = jax.lax.pmax(contrib, axis)                    # argmax combine

    new = vmax + em_t
    eye = jnp.arange(K, dtype=jnp.int32)
    return jnp.where(is_pad, delta, new), jnp.where(is_pad, eye, psi)


def _tp_dp_step_col(delta, log_A_local, em_local, is_pad, axis: str):
    """Column(target)-sharded DP step — §Perf iteration 2.

    Row-sharding needs two all-reduce-MAX combines per step (values +
    argmax-packing).  Sharding log_A by TARGET columns instead gives each
    shard its own delta'/psi slice computed over ALL sources locally — the
    combine becomes two plain all-gathers of K/mp-slices (half the link bytes
    under ring accounting, and no argmax packing)."""
    K = delta.shape[0]
    kl = log_A_local.shape[1]
    shard = jax.lax.axis_index(axis)

    scores = delta[:, None] + log_A_local               # (K, K/mp)
    part_val = jnp.max(scores, axis=0) + em_local       # (K/mp,)
    part_psi = jnp.argmax(scores, axis=0).astype(jnp.int32)

    new = jax.lax.all_gather(part_val, axis, tiled=True)     # (K,)
    psi = jax.lax.all_gather(part_psi, axis, tiled=True)
    eye = jnp.arange(K, dtype=jnp.int32)
    return jnp.where(is_pad, delta, new), jnp.where(is_pad, eye, psi)


def _tp_initial_pass(log_pi, log_A_local, em, pad, boundaries, axis: str,
                     dp_step=None):
    """TP-sharded version of flash._initial_pass (runs inside shard_map).

    em is (Tp, K) for the row layout or (Tp, K/mp) for the column layout;
    delta/psi/div always track the full K (gathered)."""
    dp_step = dp_step or _tp_dp_step
    Tp = em.shape[0]
    K = log_A_local.shape[1] if dp_step is _tp_dp_step else log_A_local.shape[0]
    nb = boundaries.shape[0]
    bnd = boundaries

    if dp_step is _tp_dp_step_col:
        d0_local = jax.lax.dynamic_slice(
            log_pi, (jax.lax.axis_index(axis) * em.shape[1],),
            (em.shape[1],)) + em[0]
        delta0 = jax.lax.all_gather(d0_local, axis, tiled=True)
    else:
        delta0 = log_pi + em[0]
    div0 = jnp.zeros((K, nb), dtype=jnp.int32)

    def step(carry, inp):
        delta, div = carry
        em_t, is_pad, t = inp
        new, psi = dp_step(delta, log_A_local, em_t, is_pad, axis)
        just = (t == bnd + 1)
        div_new = jnp.where(just[None, :], psi[:, None], div[psi, :])
        return (new, div_new), None

    ts = jnp.arange(1, Tp, dtype=jnp.int32)
    (delta_T, div_T), _ = jax.lax.scan(step, (delta0, div0), (em[1:], pad[1:], ts))
    q_last = jnp.argmax(delta_T).astype(jnp.int32)
    return div_T[q_last, :], q_last, delta_T[q_last]


def _tp_segment_decode(log_pi, log_A_local, em_seg, pad_seg, entry, exit_state,
                       is_first, axis: str, dp_step=None):
    """TP-sharded version of flash._segment_decode (inside shard_map; vmapped
    over the shard's local tiles — the collectives vectorise across tiles)."""
    dp_step = dp_step or _tp_dp_step
    s = em_seg.shape[0]
    shard = jax.lax.axis_index(axis)

    if dp_step is _tp_dp_step_col:
        K = log_A_local.shape[0]
        tm = s // 2 - 1
        # pruned re-init: every shard owns the full `entry` row's local columns
        row_local = log_A_local[entry]                         # (K/mp,)
        pi_local = jax.lax.dynamic_slice(
            log_pi, (shard * em_seg.shape[1],), (em_seg.shape[1],))
        d0_local = jnp.where(is_first, pi_local, row_local) + em_seg[0]
        delta0 = jax.lax.all_gather(d0_local, axis, tiled=True)
    else:
        K = log_A_local.shape[1]
        tm = s // 2 - 1
        kl = log_A_local.shape[0]
        row0 = shard * kl
        # pruned re-init needs row log_A[entry]: only one shard owns it -> pmax
        local_has = (entry >= row0) & (entry < row0 + kl)
        local_row = log_A_local[jnp.clip(entry - row0, 0, kl - 1)]
        # flashlint: disable=FL007(pmax reduction identity for the non-owning shards, not an allowed-set mask)
        row = jax.lax.pmax(jnp.where(local_has, local_row, NEG_INF * 2), axis)
        delta0 = jnp.where(is_first, log_pi + em_seg[0], row + em_seg[0])
    mid0 = jnp.zeros((K,), dtype=jnp.int32)

    def step(carry, inp):
        delta, mid = carry
        em_t, is_pad, tl = inp
        new, psi = dp_step(delta, log_A_local, em_t, is_pad, axis)
        mid_new = jnp.where(tl == tm + 1, psi, mid[psi])
        return (new, mid_new), None

    tls = jnp.arange(1, s, dtype=jnp.int32)
    (_, mid_T), _ = jax.lax.scan(step, (delta0, mid0), (em_seg[1:], pad_seg[1:], tls))
    return mid_T[exit_state]


# ---------------------------------------------------------------------------
# 2-D sharded FLASH decoder
# ---------------------------------------------------------------------------

def make_flash_viterbi_2d(mesh: Mesh, T: int, K: int, parallelism: int | None = None,
                          data_axis: str = "data", model_axis: str = "model",
                          shard: str = "row"):
    """Build a jitted 2-D-parallel FLASH decoder for fixed (T, K).

    Layer tiles shard over `data_axis` (the paper's P := data-axis size);
    each DP step shards log_A over `model_axis`: shard="row" (sources,
    all-reduce-MAX combines — the baseline) or shard="col" (targets, plain
    all-gathers + local psi — §Perf iteration 2, ~2x fewer link bytes).
    Returns decode(log_pi, log_A, em) -> (path (T,), score).
    """
    dp = mesh.shape[data_axis]
    mp = mesh.shape[model_axis]
    P_par = parallelism or dp
    assert K % mp == 0, f"K={K} must divide model axis {mp}"
    Tp, L = plan_padding(T, P_par)
    dp_step = _tp_dp_step_col if shard == "col" else _tp_dp_step
    a_spec = P(None, model_axis) if shard == "col" else P(model_axis, None)
    em_spec = P(None, model_axis) if shard == "col" else P()
    em_tile_spec = (P(data_axis, None, model_axis) if shard == "col"
                    else P(data_axis, None, None))
    em_tile_repl = (P(None, None, model_axis) if shard == "col"
                    else P(None, None, None))

    seg0 = Tp // P_par
    boundaries = (np.arange(1, P_par) * seg0 - 1).astype(np.int32)

    def _initial(log_pi, log_A_local, em, pad):
        return _tp_initial_pass(log_pi, log_A_local, em, pad,
                                jnp.asarray(boundaries), model_axis,
                                dp_step=dp_step)

    initial_sharded = shard_map(
        _initial, mesh=mesh,
        in_specs=(P(), a_spec, em_spec, P()),
        out_specs=(P(), P(), P()),
        check_replication=False)

    def _layer(log_pi, log_A_local, em_tiles, pad_tiles, entries, exits, firsts):
        fn = partial(_tp_segment_decode, axis=model_axis, dp_step=dp_step)
        return jax.vmap(
            lambda e, pd, en, ex, fi: fn(log_pi, log_A_local, e, pd, en, ex, fi)
        )(em_tiles, pad_tiles, entries, exits, firsts)

    def decode(log_pi, log_A, em):
        em_p, pad = pad_emissions(em, Tp)
        q_bounds, q_last, score = initial_sharded(log_pi, log_A, em_p, pad)

        q_star = jnp.zeros((Tp,), dtype=jnp.int32)
        q_star = q_star.at[Tp - 1].set(q_last)
        if P_par > 1:
            q_star = q_star.at[jnp.asarray(boundaries)].set(q_bounds)

        s = seg0
        while s >= 2:
            n = Tp // s
            starts = np.arange(n, dtype=np.int64) * s
            em_tiles = em_p.reshape(n, s, K)
            pad_tiles = pad.reshape(n, s)
            entries = q_star[jnp.asarray(np.maximum(starts - 1, 0))]
            exits = q_star[jnp.asarray(starts + s - 1)]
            firsts = jnp.asarray(starts == 0)

            if n % dp == 0:  # shard tiles over the data axis
                layer_sharded = shard_map(
                    _layer, mesh=mesh,
                    in_specs=(P(), a_spec,
                              em_tile_spec, P(data_axis, None),
                              P(data_axis), P(data_axis), P(data_axis)),
                    out_specs=P(data_axis),
                    check_replication=False)
            else:  # thin layers stay replicated over data (still TP over model)
                layer_sharded = shard_map(
                    _layer, mesh=mesh,
                    in_specs=(P(), a_spec,
                              em_tile_repl, P(None, None),
                              P(None), P(None), P(None)),
                    out_specs=P(None),
                    check_replication=False)
            mids = layer_sharded(log_pi, log_A, em_tiles, pad_tiles,
                                 entries, exits, firsts)
            q_star = q_star.at[jnp.asarray(starts + s // 2 - 1)].set(mids)
            s //= 2
        return q_star[:T], score

    repl = NamedSharding(mesh, P())
    return jax.jit(decode, in_shardings=(repl, repl, repl),
                   out_shardings=(repl, repl))


BATCHED_DECODER_METHODS = ("vanilla", "flash", "flash_bs", "fused")


def make_batched_flash_decoder(mesh: Mesh, data_axis: str = "data",
                               method: str = "flash", *,
                               spec=None,
                               parallelism: int = 8, lanes: int | None = None,
                               beam_width: int = 128, chunk: int = 128,
                               bt: int = 8):
    """Batch-of-sequences serving decoder: sequences shard over `data_axis`.

    Built on `core.batch.viterbi_decode_batch` (the single entry point every
    serving path goes through), so it inherits the ragged-``lengths``
    contract: pad frames run as tropical-identity steps, scores carry no
    pad-transition mass, and each sequence's result is bit-identical to a
    single-device unbatched decode of its unpadded payload.

    Args:
      mesh: the device mesh; ``mesh.shape[data_axis]`` must divide B.
      spec: a batchable `core.DecodeSpec` — the preferred form; supplies the
        method and all tunables (``method``/``parallelism``/``lanes``/``bt``
        are then ignored).
      method: legacy string form — ``vanilla`` (masked-scan oracle), ``flash``
        (wavefront, fully vectorised per sequence with lanes=None by
        default), ``flash_bs`` (dynamic beam), or ``fused`` (batch-grid
        Pallas kernel).
      parallelism / lanes / beam_width / chunk / bt: forwarded to
        `viterbi_decode_batch` (beam_width/chunk only matter for flash_bs).

    Returns a jitted ``decode(log_pi, log_A, ems (B, T, K), lengths (B,))
    -> (paths (B, T), scores (B,))``.
    """
    from .batch import viterbi_decode_batch
    if spec is not None:
        if spec.batch_method is None:
            raise ValueError(f"{type(spec).__name__} has no batched path; "
                             f"choose a spec whose method is in "
                             f"{BATCHED_DECODER_METHODS}")
        method = spec.batch_method
        tunables = spec.batch_tunables()
    else:
        if method not in BATCHED_DECODER_METHODS:
            raise ValueError(f"unknown method {method!r}; choose from "
                             f"{BATCHED_DECODER_METHODS}")
        tunables = dict(parallelism=parallelism, lanes=lanes,
                        beam_width=beam_width, chunk=chunk, bt=bt)

    def decode(log_pi, log_A, ems, lengths):
        return viterbi_decode_batch(ems, log_pi, log_A, lengths,
                                    method=method, mesh=mesh,
                                    data_axis=data_axis, **tunables)

    repl = NamedSharding(mesh, P())
    return jax.jit(
        decode,
        in_shardings=(repl, repl,
                      NamedSharding(mesh, P(data_axis, None, None)),
                      NamedSharding(mesh, P(data_axis))),
        out_shardings=(NamedSharding(mesh, P(data_axis, None)),
                       NamedSharding(mesh, P(data_axis))))


__all__ = ["make_flash_viterbi_2d", "make_batched_flash_decoder",
           "BATCHED_DECODER_METHODS"]
