"""HMM substrate: log-domain model container, synthetic generators, scoring helpers.

Everything downstream (the decoders in this package, the serving alignment head,
the benchmarks) consumes the unified log-domain representation defined here:

  * ``log_pi``   -- (K,)   initial state log-probabilities
  * ``log_A``    -- (K, K) transition log-probabilities, ``log_A[i, j] = log P(j | i)``
  * ``log_B``    -- (K, M) emission log-probabilities for discrete observations
  * emissions    -- (T, K) per-timestep state log-likelihoods (``log_B[:, x_t].T`` for
                    discrete observations, or neural-network frame posteriors for the
                    forced-alignment / serving paths)

Missing transitions (Erdős–Rényi graphs with edge probability p < 1) are encoded as
``NEG_INF`` (a large finite negative) rather than ``-inf`` so that float32 max-plus
arithmetic never produces NaNs while remaining far below any reachable path score.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

# Large finite "minus infinity".  T * |NEG_INF| must stay well inside float32 range;
# 2^20 timesteps * 1e9 = 1e15 << 3.4e38, so even the 500k-step long-context decode
# path cannot overflow.
NEG_INF = -1.0e9


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class HMM:
    """Log-domain HMM parameter triplet (pi, A, B)."""

    log_pi: jax.Array  # (K,)
    log_A: jax.Array   # (K, K)
    log_B: jax.Array   # (K, M)

    @property
    def num_states(self) -> int:
        return self.log_A.shape[0]

    @property
    def num_obs(self) -> int:
        return self.log_B.shape[1]

    def emissions(self, obs: jax.Array) -> jax.Array:
        """Dense per-timestep emission scores, shape (T, K), for int obs (T,)."""
        return jnp.take(self.log_B, obs, axis=1).T

    # -- pytree protocol ------------------------------------------------------
    def tree_flatten(self):
        return (self.log_pi, self.log_A, self.log_B), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(*children)


# ---------------------------------------------------------------------------
# Synthetic model generators (paper Sec. VII-A)
# ---------------------------------------------------------------------------

def erdos_renyi_hmm(
    key: jax.Array,
    num_states: int,
    num_obs: int = 50,
    edge_prob: float = 0.253,
    ensure_connected: bool = True,
) -> HMM:
    """Random HMM whose transition graph is G(K, p), as in the paper's experiments.

    Every present edge gets a Dirichlet-ish random weight (renormalised over the
    out-edges of each state); absent edges get ``NEG_INF``.  ``ensure_connected``
    adds a ring lattice so every state has at least one in- and out-edge, keeping
    all decoding problems feasible at any p.
    """
    k_edges, k_trans, k_pi, k_emit = jax.random.split(key, 4)
    mask = jax.random.bernoulli(k_edges, edge_prob, (num_states, num_states))
    if ensure_connected:
        ring = jnp.eye(num_states, dtype=bool)
        ring = jnp.roll(ring, 1, axis=1)  # i -> i+1 mod K
        mask = mask | ring
    raw = jax.random.uniform(k_trans, (num_states, num_states), minval=0.05, maxval=1.0)
    weights = jnp.where(mask, raw, 0.0)
    row_sum = jnp.sum(weights, axis=1, keepdims=True)
    probs = weights / row_sum
    # flashlint: disable=FL007(model generator defining log_A itself; this IS the dense input constraints mask against)
    log_A = jnp.where(mask, jnp.log(jnp.maximum(probs, 1e-30)), NEG_INF)

    pi = jax.random.dirichlet(k_pi, jnp.ones((num_states,)) * 0.8)
    log_pi = jnp.log(jnp.maximum(pi, 1e-30))

    emit = jax.random.dirichlet(k_emit, jnp.ones((num_obs,)) * 0.5, (num_states,))
    log_B = jnp.log(jnp.maximum(emit, 1e-30))
    return HMM(log_pi=log_pi, log_A=log_A, log_B=log_B)


def left_to_right_hmm(
    key: jax.Array,
    num_states: int,
    num_obs: int,
    self_loop: float = 0.6,
    max_skip: int = 2,
) -> HMM:
    """Bakis (left-to-right) HMM used by forced alignment (paper Sec. VII-A TIMIT)."""
    k_emit, k_noise = jax.random.split(key)
    idx = jnp.arange(num_states)
    delta = idx[None, :] - idx[:, None]  # j - i
    allowed = (delta >= 0) & (delta <= max_skip)
    base = jnp.where(delta == 0, self_loop, (1.0 - self_loop) / max_skip)
    noise = jax.random.uniform(k_noise, (num_states, num_states), minval=0.8, maxval=1.2)
    weights = jnp.where(allowed, base * noise, 0.0)
    # last rows renormalise over remaining allowed targets
    probs = weights / jnp.maximum(jnp.sum(weights, axis=1, keepdims=True), 1e-30)
    # flashlint: disable=FL007(model generator defining the left-to-right log_A, not a decode-time mask)
    log_A = jnp.where(allowed, jnp.log(jnp.maximum(probs, 1e-30)), NEG_INF)
    log_pi = jnp.full((num_states,), NEG_INF).at[0].set(0.0)
    emit = jax.random.dirichlet(k_emit, jnp.ones((num_obs,)) * 0.5, (num_states,))
    log_B = jnp.log(jnp.maximum(emit, 1e-30))
    return HMM(log_pi=log_pi, log_A=log_A, log_B=log_B)


def sample_observations(key: jax.Array, hmm: HMM, length: int) -> tuple[jax.Array, jax.Array]:
    """Ancestral sampling of (hidden states, observations) of given length."""
    k0, key = jax.random.split(key)
    s0 = jax.random.categorical(k0, hmm.log_pi)

    def step(carry, k):
        s = carry
        ka, kb = jax.random.split(k)
        s_next = jax.random.categorical(ka, hmm.log_A[s])
        o = jax.random.categorical(kb, hmm.log_B[s])
        return s_next, (s, o)

    keys = jax.random.split(key, length)
    _, (states, obs) = jax.lax.scan(step, s0, keys)
    return states, obs


# ---------------------------------------------------------------------------
# Scoring helpers
# ---------------------------------------------------------------------------

def path_score(log_pi: jax.Array, log_A: jax.Array, emissions: jax.Array,
               path: jax.Array) -> jax.Array:
    """Log-likelihood of a concrete state path under (pi, A, emissions)."""
    first = log_pi[path[0]] + emissions[0, path[0]]
    trans = log_A[path[:-1], path[1:]]
    emit = jnp.take_along_axis(emissions[1:], path[1:, None], axis=1)[:, 0]
    return first + jnp.sum(trans) + jnp.sum(emit)


def relative_error(opt_ll: jax.Array, ll: jax.Array) -> jax.Array:
    """Paper Sec. VII-D metric: eta = |l_opt - l| / |l_opt|."""
    return jnp.abs(opt_ll - ll) / jnp.abs(opt_ll)


def random_emissions(key: jax.Array, length: int, num_states: int,
                     scale: float = 2.0) -> jax.Array:
    """Well-separated random emissions (ties have measure ~0) for tests/benches."""
    return scale * jax.random.normal(key, (length, num_states))


__all__ = [
    "HMM",
    "NEG_INF",
    "erdos_renyi_hmm",
    "left_to_right_hmm",
    "sample_observations",
    "path_score",
    "relative_error",
    "random_emissions",
]
