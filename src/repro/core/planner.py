"""Budget -> spec planning: the paper's adaptivity story as a first-class API.

Two pieces live here:

* **The memory cost model.** `decoder_state_bytes(method, K, T, P, B)` — the
  analytic live-DP-state formulas the paper's Fig. 1/7/9 track (RSS on a JIT
  runtime measures the allocator, not the algorithm).  This used to live in
  `benchmarks/common.py`; it is core now, so benchmarks and examples import
  it *from* core and never the reverse.  `spec_state_bytes(spec, K, T)` is
  the typed view of the same model.

* **The degradation ladder.** `plan(K, T, budget)` turns a `ResourceBudget`
  into a `DecodePlan` — a concrete `DecodeSpec` plus a human-readable `why`.
  The policy is the paper's Sec. V-C-3 (previously a private helper in
  `examples/adaptive_edge.py`): prefer the exact decoder at the largest
  parallelism that fits, then shrink P, then fall back to the dynamic beam
  (widest beam first), then the floor config.  The ladder is ordered so a
  smaller budget can never yield a larger-footprint plan (pinned by
  `tests/test_api.py`).
"""

from __future__ import annotations

import dataclasses
import math

from .constraints import ConstraintSpec, banded_state_bytes
from .spec import DecodeSpec, FlashSpec, FlashBSSpec, FusedSpec, ResourceBudget

__all__ = ["decoder_state_bytes", "spec_state_bytes", "DecodePlan", "plan",
           "IR_STATE_FACTOR", "crosscheck_state_bytes",
           "online_session_bytes", "inflight_state_bytes",
           "AdmissionPlan", "plan_admission"]


def decoder_state_bytes(method: str, K: int, T: int, P: int = 8,
                        B: int = 128) -> int:
    """Live DP-state bytes per the complexity table (paper Fig. 1).

    4-byte scores + 4-byte indices; FLASH tracks (OptProb, PreState-equivalent,
    MidState/DivState); beams track (score, state, mid) per slot.
    """
    if method in ("vanilla", "fused", "online"):
        # full psi table + delta; `fused` streams the same table through the
        # kernel, `online` holds it as the worst-case commit window.
        return K * T * 4 + K * 8
    if method == "checkpoint":
        c = int(math.ceil(math.sqrt(T)))
        return K * c * 4 + K * c * 4 + K * 8     # checkpoints + segment psis
    if method in ("sieve", "sieve_mp"):
        return K * 12                            # delta + mid + entry vector
    if method == "flash":
        return P * K * 12 + (P - 1) * K * 4      # P lanes + DivState
    if method == "flash_bs":
        return P * B * 12 + (P - 1) * B * 4
    if method == "online_beam":
        # streaming beam: worst case the commit window never converges, so up
        # to T slot-pointer rows (state + from, 4B each, per slot) stay live
        # on top of the O(B) beam carry.  Expected window is O(B log B), but
        # the planner must bound, not hope (analysis/contracts.py checks the
        # measured peak never exceeds this).
        return T * B * 8 + B * 12
    if method == "beam_static":
        return K * 4 + T * B * 8                 # full-K transient + survivors
    if method == "beam_static_mp":
        return K * 4 + P * B * 12                # full-K transient per step
    if method == "assoc":
        return T * K * K * 4
    raise ValueError(method)


def spec_state_bytes(spec: DecodeSpec, K: int, T: int) -> int:
    """Cost-model bytes for a typed spec (the planner's fitness function).

    A constrained spec pays for its compiled penalty masks on top of the
    method's DP state — except the banded fused path, which never
    materialises K-wide rows and is costed by `banded_state_bytes` (this is
    how a tight `BandConstraint` keeps exact decoding on the ladder at
    budgets where the dense methods have long since degraded to beams).
    """
    P = getattr(spec, "parallelism", 1)
    B = getattr(spec, "beam_width", 128)
    base = decoder_state_bytes(spec.method, K, T, P=P, B=B)
    c = spec.constraint
    if c is None:
        return base
    band = c.band()
    if spec.method == "fused" and band is not None and len(band[0]) >= T:
        return banded_state_bytes(K, T, band[1])
    return base + c.mask_bytes(K, T)


#: PV104 headroom per method: how far the jaxpr-derived DP-state bytes
#: (`analysis.jaxpr_check.dp_state_bytes`) may sit above the formula before
#: the cross-check fails.  The IR metric counts a nested scan's carry in up
#: to three places at once (previous carry still live, one body iteration's
#: working copy, carry-out) where execution donates a single buffer — so the
#: two methods whose hot loop is a scan-in-scan (beam transition streaming K
#: chunks inside the time-step scan) legitimately measure ~2-3x the modeled
#: carry.  Everything else must match the formula essentially exactly.
#: These are pinned ceilings: tightening is free, raising one means either
#: the implementation grew real state or the formula shrank — both must be
#: argued in review, not absorbed silently.
IR_STATE_FACTOR: dict[str, float] = {
    "vanilla": 1.0,
    "checkpoint": 1.15,      # replay psi stack + checkpoint row overlap
    "flash": 1.0,
    "flash_bs": 2.5,         # scan-in-scan carry multi-count (see above)
    "online_beam": 1.0,
    "beam_static": 1.0,
    "beam_static_mp": 3.0,   # same hot loop as flash_bs, smaller model
    "assoc": 1.0,
    "fused": 1.0,
    "online": 1.0,
}


def crosscheck_state_bytes(spec: DecodeSpec, K: int, T: int, ir_bytes: int,
                           batch: int = 1) -> str | None:
    """Formula-vs-IR validation of the cost model (flashprove rule PV104).

    `ir_bytes` is the jaxpr-derived peak DP-state of the traced decode
    (loop carries + stacked scan outputs + kernel output buffers).  The
    formula must upper-bound it within the pinned `IR_STATE_FACTOR` plus an
    additive slack for the threaded path itself (T int32 stacked + its
    backtrack counter — the model deliberately excludes the *output*).

    Returns None when the model holds, else a human-readable error.  This
    tightens PR 6's formula-vs-allocator contract (8-96x tolerances against
    `memory_analysis()`) to formula-vs-IR at ~1x.
    """
    model = spec_state_bytes(spec, K, T) * batch
    factor = IR_STATE_FACTOR[spec.method]
    slack = 8 * T * batch + 256
    bound = int(model * factor) + slack
    if ir_bytes <= bound:
        return None
    return (f"decoder_state_bytes({spec.method!r}, K={K}, T={T})"
            f"{f' x batch {batch}' if batch > 1 else ''} = {model:,}B "
            f"but the traced jaxpr retains {ir_bytes:,}B of DP state "
            f"(> bound {bound:,}B = model x {factor} + path slack); the "
            f"cost model underestimates the implementation")


def online_session_bytes(K: int, block: int, max_lag: int | None = None,
                         horizon: int | None = None) -> int:
    """Worst-case host-side live bytes of one inflight session.

    A slot session holds the exact-decoder commit window (up to `max_lag`
    backpointer rows of K int32 when lag is bounded, else up to `horizon`
    rows — the caller's worst-case sequence length), the K-float frontier,
    and at most one block of buffered emissions awaiting the next `step()`.
    This is the admission controller's unit cost: rows x K x 4 mirrors
    `decoder_state_bytes("online", ...)`, the block buffer is the serving
    tier's own addition.
    """
    if max_lag is not None:
        rows = int(max_lag)
    elif horizon is not None:
        rows = int(horizon)
    else:
        raise ValueError("online_session_bytes needs max_lag or horizon "
                         "to bound the commit window")
    return rows * K * 4 + K * 8 + block * K * 4


def inflight_state_bytes(K: int, block: int, slots: int) -> int:
    """Device-side persistent bytes of the inflight scheduler's batched step.

    Per slot: the carried delta row (K f32), the staged emission block and
    its psi output (block x K f32/i32 each), the fresh-seed emission row
    (K f32), and the nfeed/fresh scalars.  This is the PV104 model for the
    `jaxpr:inflight` traced entry point — the scheduler's footprint is
    fixed at construction and independent of how many sessions ever pass
    through it.
    """
    per_slot = K * 4 * (2 * block + 3) + 16
    return slots * per_slot


@dataclasses.dataclass(frozen=True)
class AdmissionPlan:
    """An admission decision: the commit-lag bound to run the session at.

    `max_lag=None` means the exact (unbounded-window) decode was affordable;
    a degraded plan bounds the window, trading forced-flush approximation on
    pathological inputs for a hard memory ceiling, exactly the paper's
    degradation story applied to the serving tier.
    """
    max_lag: int | None
    state_bytes: int
    why: str
    degraded: bool


# Commit-lag degradation ladder for admission control: when the requested
# window does not fit the remaining budget, walk down until one does.  Widest
# first, so the least approximation that fits wins (mirrors the `plan` ladder's
# first-fit ordering).
_LAG_LADDER = (1024, 512, 256, 128, 64, 32, 16, 8)


def plan_admission(K: int, block: int, remaining_bytes: int | None, *,
                   requested_lag: int | None = None,
                   horizon: int = 4096) -> AdmissionPlan | None:
    """Fit one streaming session into what's left of a `ResourceBudget`.

    Args:
      K, block: state count and the scheduler's block size.
      remaining_bytes: budget headroom left after currently-admitted
        sessions (None = unlimited).
      requested_lag: the session's own `max_lag` (None = exact decode,
        costed at the worst-case `horizon`-row window).
      horizon: worst-case sequence length used to cost an exact session.

    Returns the `AdmissionPlan` to admit under, or None when even the
    tightest ladder rung exceeds the remaining budget (caller queues or
    rejects).  A returned plan never loosens the caller's request: ladder
    rungs at or above `requested_lag` are skipped.
    """
    def cost(lag: int | None) -> int:
        return online_session_bytes(K, block, max_lag=lag, horizon=horizon)

    asked = cost(requested_lag)
    if remaining_bytes is None or asked <= remaining_bytes:
        kind = "exact" if requested_lag is None else f"max_lag={requested_lag}"
        return AdmissionPlan(max_lag=requested_lag, state_bytes=asked,
                             why=f"as requested ({kind}, {asked:,}B)",
                             degraded=False)
    ceiling = requested_lag if requested_lag is not None else horizon
    for lag in _LAG_LADDER:
        if lag >= ceiling:
            continue
        bytes_ = cost(lag)
        if bytes_ <= remaining_bytes:
            return AdmissionPlan(
                max_lag=lag, state_bytes=bytes_, degraded=True,
                why=(f"degraded to max_lag={lag} ({bytes_:,}B <= remaining "
                     f"{remaining_bytes:,}B; requested window cost "
                     f"{asked:,}B)"))
    return None


@dataclasses.dataclass(frozen=True)
class DecodePlan:
    """A planner decision: the spec to run plus the reasoning behind it.

    state_bytes is the cost-model estimate for the *whole* planned workload
    (per-sequence bytes x batch when a batch size was planned for).
    """
    spec: DecodeSpec
    why: str
    state_bytes: int
    K: int
    T: int
    batch: int | None = None
    budget: ResourceBudget | None = None


# Paper Sec. V-C-3 ladder, exactly the old examples/adaptive_edge.choose_config
# ordering: exact at descending P, then beams widest-first with descending P,
# then the floor.  First fit wins, so footprint is monotone in the budget.
_EXACT_P = (16, 8, 4, 2, 1)
_BEAM_B = (256, 128, 64, 32)
_BEAM_P = (8, 4, 1)
_FLOOR = FlashBSSpec(parallelism=1, beam_width=16)


def plan(K: int, T: int,
         budget: ResourceBudget | int | None = None,
         batch: int | None = None,
         constraint: ConstraintSpec | None = None) -> DecodePlan:
    """Pick the best-fitting decoder spec for a (K, T) workload.

    Args:
      K, T: state count and sequence length of the workload.
      budget: a `ResourceBudget`, a raw byte count (shorthand for
        ``ResourceBudget(memory_bytes=...)``), or None (unlimited).
      batch: optional number of sequences decoded together; the footprint is
        per-sequence bytes x batch, and the chosen spec is guaranteed to be a
        `viterbi_decode_batch` method.
      constraint: optional `ConstraintSpec` the workload decodes under.
        Every rung carries it (its mask bytes count against the budget), and
        a `BandConstraint` covering the horizon adds an exact banded-fused
        rung between the exact and beam rungs — so a tight constraint keeps
        exact decoding alive at budgets where the dense ladder has already
        degraded to beams.

    Returns a `DecodePlan`; `.spec` is ready for `ViterbiDecoder` and
    `.why` says which ladder rung fired and what it cost.
    """
    if isinstance(budget, int):
        budget = ResourceBudget(memory_bytes=budget)
    budget = budget or ResourceBudget()
    if batch is not None and batch < 1:
        raise ValueError(f"batch must be >= 1, got {batch}")
    scale = int(batch) if batch is not None else 1
    cap = budget.memory_bytes

    def fits(spec: DecodeSpec) -> int | None:
        bytes_ = spec_state_bytes(spec, K, T) * scale
        return bytes_ if cap is None or bytes_ <= cap else None

    def mk(spec, why, bytes_):
        per = " per batch" if batch else ""
        cap_s = ""
        if cap is not None:
            rel = "<=" if bytes_ <= cap else "exceeds"
            cap_s = f" {rel} budget {cap:,}B"
        return DecodePlan(spec=spec, why=f"{why} (state {bytes_:,}B{per}{cap_s})",
                          state_bytes=bytes_, K=K, T=T, batch=batch,
                          budget=budget)

    exact_ps = (_EXACT_P if budget.latency_hint != "memory"
                else tuple(reversed(_EXACT_P)))
    for P in exact_ps:
        spec = FlashSpec(parallelism=P, constraint=constraint)
        bytes_ = fits(spec)
        if bytes_ is not None:
            return mk(spec, f"exact, P={P}", bytes_)
    # still exact, far smaller state: the banded fused path (single-sequence
    # only — the batched fused kernel applies the band as fused penalty adds
    # instead, whose footprint the rungs above already modeled).
    band = constraint.band() if constraint is not None else None
    if band is not None and len(band[0]) >= T and batch is None:
        spec = FusedSpec(constraint=constraint)
        bytes_ = fits(spec)
        if bytes_ is not None:
            return mk(spec, f"exact banded fused, width={band[1]}", bytes_)
    for B in _BEAM_B:
        for P in _BEAM_P:
            spec = FlashBSSpec(parallelism=P, beam_width=B,
                               constraint=constraint)
            bytes_ = fits(spec)
            if bytes_ is not None:
                return mk(spec, f"beam, P={P}, B={B}", bytes_)
    floor = dataclasses.replace(_FLOOR, constraint=constraint)
    return mk(floor, "floor: P=1,B=16",
              spec_state_bytes(floor, K, T) * scale)
