"""FLASH Viterbi — non-recursive divide-and-conquer decoding (paper Sec. V-A/V-B).

Structure (faithful to Algorithm 1 + the P-way initial-partition optimisation):

  * **Initial pass** over the full (padded) sequence tracks, for every DP state, the
    state its best path visited at each of the P-1 interior *division points*
    (the `MidState`/`DivState` array of the paper, generalised from 1 midpoint to
    P-1 boundaries).  Backtracking pins the optimal states at all boundaries plus
    the final step.  Cost: O(K^2 T) time, O(PK) space.

  * **Layer wavefront**: the paper's task queue admits any intra-layer order, so we
    schedule it as a statically known layer-synchronous wavefront.  Layer ell has
    Tp/s contiguous tiles of length s = seg0 / 2^(ell-1); every tile's entry state
    (q*_{m-1}) and exit state (q*_n) were pinned by strictly earlier layers, which
    is exactly the paper's inter-layer ordering invariant.  Each tile resolves one
    state: its midpoint.

  * **Pruning** (paper Sec. V-B, Theorems 1-3): a tile starting at m != 0 seeds its
    DP from only the pinned entry state with score 0:
        OptProb[i] = log A[q*_{m-1}, i] + log B[i, x_m].
    This removes every cross-tile data dependency, so a whole layer is data-parallel.

  * **Parallelism degree P** maps to TPU lanes: tiles of a layer are processed in
    chunks of `lanes` via `vmap` (sequentially over chunks, matching the paper's
    "P subtasks in flight" queue semantics and its O(PK) space bound).  Setting
    `lanes=None` vectorises the whole layer (TPU throughput mode; documented
    deviation — space grows to O(K * tiles_per_layer)).

Sequences are padded to Tp = P * 2^L with tropical-identity steps (stay in place,
add 0), which provably leave every delta, backpointer, division state and the
decoded prefix unchanged.
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np



# ---------------------------------------------------------------------------
# Padding
# ---------------------------------------------------------------------------

def plan_padding(T: int, P: int) -> tuple[int, int]:
    """Return (Tp, L): padded length P * 2^L with seg0 = 2^L >= ceil(T / P)."""
    seg0 = max(1, math.ceil(T / P))
    L = max(0, math.ceil(math.log2(seg0)))
    return P * (1 << L), L


def pad_emissions(em: jax.Array, Tp: int) -> tuple[jax.Array, jax.Array]:
    T = em.shape[0]
    em_p = jnp.pad(em, ((0, Tp - T), (0, 0)))
    pad = jnp.arange(Tp) >= T
    return em_p, pad


# ---------------------------------------------------------------------------
# DP steps
# ---------------------------------------------------------------------------

def _dp_step(log_A, delta, em_t, is_pad):
    """One Viterbi DP step; pad steps are tropical-identity (delta frozen)."""
    K = log_A.shape[0]
    scores = delta[:, None] + log_A                  # (K_src, K_dst)
    psi = jnp.argmax(scores, axis=0).astype(jnp.int32)
    new = jnp.max(scores, axis=0) + em_t
    eye = jnp.arange(K, dtype=jnp.int32)
    return jnp.where(is_pad, delta, new), jnp.where(is_pad, eye, psi)


def _initial_pass(log_pi, log_A, em, pad, boundaries: np.ndarray):
    """Full-sequence DP tracking division states at `boundaries` (static indices).

    Returns (q_bounds (nb,), q_last, score): pinned states at each interior
    boundary, the optimal final state, and the optimal path log-likelihood.
    """
    Tp, K = em.shape
    nb = len(boundaries)
    bnd = jnp.asarray(boundaries, dtype=jnp.int32)

    delta0 = log_pi + em[0]
    div0 = jnp.zeros((K, nb), dtype=jnp.int32)

    def step(carry, inp):
        delta, div = carry
        em_t, is_pad, t = inp
        new, psi = _dp_step(log_A, delta, em_t, is_pad)
        just = (t == bnd + 1)            # (nb,) this step crosses boundary i
        gathered = div[psi, :]           # (K, nb) propagate along best edges
        div_new = jnp.where(just[None, :], psi[:, None], gathered)
        return (new, div_new), None

    ts = jnp.arange(1, Tp, dtype=jnp.int32)
    (delta_T, div_T), _ = jax.lax.scan(step, (delta0, div0), (em[1:], pad[1:], ts))
    q_last = jnp.argmax(delta_T).astype(jnp.int32)
    score = delta_T[q_last]
    q_bounds = div_T[q_last, :]
    return q_bounds, q_last, score


def _segment_decode(log_pi, log_A, em_seg, pad_seg, entry, exit_state, is_first):
    """Pruned subtask DP over one tile (static length s); returns q*_{midpoint}.

    `entry` is the pinned optimal state at m-1 (ignored when is_first), and
    `exit_state` the pinned optimal state at n.  Faithful to Algorithm 2 with the
    Sec. V-B pruned re-initialisation.
    """
    s, K = em_seg.shape
    tm = s // 2 - 1  # local midpoint index

    pruned0 = log_A[entry] + em_seg[0]
    first0 = log_pi + em_seg[0]
    delta0 = jnp.where(is_first, first0, pruned0)
    mid0 = jnp.zeros((K,), dtype=jnp.int32)

    def step(carry, inp):
        delta, mid = carry
        em_t, is_pad, tl = inp
        new, psi = _dp_step(log_A, delta, em_t, is_pad)
        mid_new = jnp.where(tl == tm + 1, psi, mid[psi])
        return (new, mid_new), None

    tls = jnp.arange(1, s, dtype=jnp.int32)
    (_, mid_T), _ = jax.lax.scan(step, (delta0, mid0), (em_seg[1:], pad_seg[1:], tls))
    return mid_T[exit_state]


# ---------------------------------------------------------------------------
# Lane-chunked layer execution (the task queue, statically scheduled)
# ---------------------------------------------------------------------------

def chunked_vmap(fn, args: tuple, lanes: int | None):
    """vmap `fn` over the leading axis, `lanes` tasks at a time.

    `lanes` is the paper's parallelism degree P: at most `lanes` subtasks are in
    flight, bounding live memory at O(lanes * K) while leaving intra-chunk
    execution fully parallel.  `lanes=None` runs the whole layer at once.
    """
    n = args[0].shape[0]
    vf = jax.vmap(fn)
    if lanes is None or n <= lanes:
        return vf(*args)
    nfull = (n // lanes) * lanes
    args_c = tuple(a[:nfull].reshape(n // lanes, lanes, *a.shape[1:])
                   for a in args)
    out = jax.lax.map(lambda xs: vf(*xs), args_c)
    out = out.reshape(nfull, *out.shape[2:])
    if nfull != n:  # remainder chunk: fewer than `lanes` tasks in flight
        out = jnp.concatenate([out, vf(*(a[nfull:] for a in args))], axis=0)
    return out


# ---------------------------------------------------------------------------
# Full decoder
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("P", "lanes"))
def _flash_padded(log_pi, log_A, em, pad, P: int, lanes: int | None):
    Tp, K = em.shape
    seg0 = Tp // P

    boundaries = (np.arange(1, P) * seg0 - 1).astype(np.int64)  # e_i, i < P-1
    q_bounds, q_last, score = _initial_pass(log_pi, log_A, em, pad, boundaries)

    q_star = jnp.zeros((Tp,), dtype=jnp.int32)
    q_star = q_star.at[Tp - 1].set(q_last)
    if P > 1:
        q_star = q_star.at[jnp.asarray(boundaries)].set(q_bounds)

    s = seg0
    while s >= 2:  # layer wavefront: L = log2(seg0) layers, statically unrolled
        n = Tp // s
        starts = np.arange(n, dtype=np.int64) * s
        ends = starts + s - 1
        mids = starts + s // 2 - 1
        em_tiles = em.reshape(n, s, K)
        pad_tiles = pad.reshape(n, s)
        entries = q_star[jnp.asarray(np.maximum(starts - 1, 0))]
        exits = q_star[jnp.asarray(ends)]
        is_first = jnp.asarray(starts == 0)

        fn = partial(_segment_decode, log_pi, log_A)
        mid_states = chunked_vmap(
            fn, (em_tiles, pad_tiles, entries, exits, is_first), lanes)
        q_star = q_star.at[jnp.asarray(mids)].set(mid_states)
        s //= 2
    return q_star, score


def flash_viterbi(log_pi, log_A, em, parallelism: int = 8,
                  lanes: int | None = -1):
    """FLASH Viterbi decode.

    Args:
      log_pi, log_A, em: HMM in log domain + (T, K) emissions.
      parallelism: the paper's P — width of the initial partition and the default
        number of subtask lanes in flight.
      lanes: subtasks processed concurrently per layer; -1 means "= parallelism"
        (paper semantics), None means vectorise whole layers (TPU throughput mode).

    Returns:
      (path, score): (T,) int32 optimal path and its log-likelihood.
    """
    T, K = em.shape
    P = int(parallelism)
    if lanes == -1:
        lanes = P
    if T == 1:
        q = jnp.argmax(log_pi + em[0]).astype(jnp.int32)
        return q[None], (log_pi + em[0])[q]
    Tp, _ = plan_padding(T, P)
    em_p, pad = pad_emissions(em, Tp)
    q_star, score = _flash_padded(log_pi, log_A, em_p, pad, P, lanes)
    return q_star[:T], score


#: flashprove waivers (see analysis/findings.py for the grammar).
FLASHPROVE_WAIVERS = {
    "PV103:jaxpr:flash:batch": (
        "the vmapped DP step broadcasts (batch, lanes, K, K) scores for one "
        "time step; it is per-step compute working set XLA fuses into the "
        "argmax/max reduction, never a retained table, and it scales with "
        "the lane count the planner already bounds"),
}

__all__ = [
    "flash_viterbi",
    "plan_padding",
    "pad_emissions",
    "chunked_vmap",
]
