"""Checkpoint Viterbi [Tarnas & Hughey 1998] in JAX.

Baseline #2 of the paper: store the delta vector only every ~sqrt(T) steps
(checkpoints), then re-run each segment during backtracking.  Space O(K sqrt(T)),
time 2x the vanilla forward pass.

Implemented as two nested `lax.scan`s over a (num_segments, seg_len, K) view so the
whole decode is one jitted program.  T is padded up to num_segments * seg_len with
identity steps (transition = tropical identity, emission = 0), which leave delta,
backpointers and the decoded prefix unchanged.
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp


def _identity_step(delta, K):
    """Tropical-identity DP step: stay in place, add nothing."""
    return delta, jnp.arange(K, dtype=jnp.int32)


@partial(jax.jit, static_argnames=("seg_len",))
def _checkpoint_decode(log_pi, log_A, em_padded, pad_mask, seg_len: int):
    Tp, K = em_padded.shape
    n_seg = Tp // seg_len
    em_seg = em_padded.reshape(n_seg, seg_len, K)
    mask_seg = pad_mask.reshape(n_seg, seg_len)

    def dp_step(delta, inp):
        em_t, is_pad = inp
        scores = delta[:, None] + log_A
        psi = jnp.argmax(scores, axis=0).astype(jnp.int32)
        new = jnp.max(scores, axis=0) + em_t
        psi = jnp.where(is_pad, jnp.arange(K, dtype=jnp.int32), psi)
        new = jnp.where(is_pad, delta, new)
        return new, psi

    # ---- forward: keep delta at each segment start --------------------------
    def fwd_segment(delta, seg):
        em_s, mask_s = seg
        entry = delta
        delta, _ = jax.lax.scan(dp_step, delta, (em_s, mask_s))
        return delta, entry

    delta0 = log_pi + em_padded[0]
    # segment 0's scan starts from t=1; to keep segments uniform, treat t=0 as a
    # "pre" step: entry of segment 0 is delta0 and its inner scan covers t=1..seg_len-1
    # plus the first step of segment 1 boundary.  Simpler: run the scan over all Tp
    # steps with step t=0 replaced by an identity step on delta0.
    mask0 = mask_seg.at[0, 0].set(True)  # t=0 handled by delta0 init
    delta_T, entries = jax.lax.scan(fwd_segment, delta0, (em_seg, mask0))

    q_last = jnp.argmax(delta_T).astype(jnp.int32)
    score = delta_T[q_last]

    # ---- backward: re-run each segment, then backtrack inside it ------------
    def bwd_segment(q_end, seg):
        entry, em_s, mask_s = seg
        _, psis = jax.lax.scan(dp_step, entry, (em_s, mask_s))  # (seg_len, K)

        def back(q, psi_t):
            q_prev = psi_t[q].astype(jnp.int32)
            return q_prev, q
        q_start, states = jax.lax.scan(back, q_end, psis, reverse=True)
        # states[t] is the decoded state AT step t within this segment
        return q_start, states

    _, states = jax.lax.scan(
        bwd_segment, q_last, (entries, em_seg, mask0), reverse=True)
    path = states.reshape(Tp)
    return path, score


def viterbi_checkpoint(log_pi, log_A, em, seg_len: int | None = None):
    """Checkpoint Viterbi decode. Returns ((T,) path, score)."""
    T, K = em.shape
    if seg_len is None:
        seg_len = max(1, int(math.ceil(math.sqrt(T))))
    Tp = int(math.ceil(T / seg_len)) * seg_len
    em_p = jnp.pad(em, ((0, Tp - T), (0, 0)))
    mask = jnp.arange(Tp) >= T
    path, score = _checkpoint_decode(log_pi, log_A, em_p, mask, seg_len)
    return path[:T], score


__all__ = ["viterbi_checkpoint"]
