"""First-class batched decoding — `viterbi_decode_batch` over (B, T, K).

Batch-axis parallelism is where decoding throughput comes from on wide
hardware (cf. the GPU Viterbi literature): one launch amortises the
transition-matrix load and the dispatch overhead over the whole request
bucket.  This module is the single entry point serving goes through.

Ragged batches are the normal case in serving, so `lengths` is part of the
contract rather than an afterthought: sequence i is decoded *exactly* at
length `lengths[i]`, with the tail realised as tropical-identity pad steps
(stay in place, add 0 — the masking machinery shared with `flash.pad_emissions`
/ `flash._dp_step`, which provably leaves deltas, backpointers, and scores
unchanged).  Scores therefore contain no pad-transition mass and per-sequence
results are bit-identical to looped `viterbi_decode` calls for the exact
methods; `tests/test_batch.py` pins this.

Methods:
  * ``fused``    — batch-grid Pallas kernel (`kernels.ops.viterbi_decode_fused_batch`):
                   grid (B, T/bt), log_A resident in VMEM for the whole bucket.
  * ``vanilla``  — vmapped masked lax.scan (exact oracle).
  * ``flash``    — vmapped FLASH wavefront; ragged masks ride the same pad
                   machinery the algorithm already uses for its P·2^L padding.
  * ``flash_bs`` — vmapped FLASH-BS dynamic beam (exact when beam_width >= K).

Path entries at padded steps repeat the sequence's final decoded state
(identity backpointers); slice row i to [:lengths[i]] for the true path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .vanilla import viterbi_vanilla_masked
from .flash import plan_padding, _flash_padded
from .flash_bs import pad_state_space, _flash_bs_padded

BATCH_METHODS = ("vanilla", "flash", "flash_bs", "fused")


def _pad_mask(T: int, lengths: jax.Array) -> jax.Array:
    return jnp.arange(T)[None, :] >= lengths[:, None]    # (B, T) True == pad


def _vanilla_batch(log_pi, log_A, em, pad):
    return jax.vmap(
        lambda e, p: viterbi_vanilla_masked(log_pi, log_A, e, p))(em, pad)


def _flash_batch(log_pi, log_A, em, pad, P: int, lanes):
    B, T, K = em.shape
    Tp, _ = plan_padding(T, P)
    em_p = jnp.pad(em, ((0, 0), (0, Tp - T), (0, 0)))
    pad_p = jnp.pad(pad, ((0, 0), (0, Tp - T)), constant_values=True)
    q, s = jax.vmap(
        lambda e, p: _flash_padded(log_pi, log_A, e, p, P, lanes))(em_p, pad_p)
    return q[:, :T], s


def _flash_bs_batch(log_pi, log_A, em, pad, beam_width: int, P: int, lanes,
                    chunk: int):
    B, T, K = em.shape
    Bw = int(min(beam_width, K))
    chunk = int(min(chunk, K))
    log_pi, log_A, em, _ = pad_state_space(log_pi, log_A, em, chunk)
    Tp, _ = plan_padding(T, P)
    em_p = jnp.pad(em, ((0, 0), (0, Tp - T), (0, 0)))
    pad_p = jnp.pad(pad, ((0, 0), (0, Tp - T)), constant_values=True)
    q, s = jax.vmap(
        lambda e, p: _flash_bs_padded(log_pi, log_A, e, p, P, lanes, Bw,
                                      chunk))(em_p, pad_p)
    return q[:, :T], s


def viterbi_decode_batch(
    emissions: jax.Array,
    log_pi: jax.Array,
    log_A: jax.Array,
    lengths: jax.Array | None = None,
    method: str = "fused",
    *,
    parallelism: int = 8,
    lanes: int | None = -1,
    beam_width: int = 128,
    chunk: int = 128,
    bt: int = 8,
) -> tuple[jax.Array, jax.Array]:
    """Decode a (possibly ragged) batch of emission sequences.

    Args:
      emissions: (B, T, K) emission log-likelihoods, row i real for the first
        lengths[i] steps (pad frames may hold anything — they are masked).
      log_pi, log_A: shared HMM in log domain.
      lengths: optional (B,) int true lengths in [1, T]; None means every
        sequence is full-length.
      method: one of ``BATCH_METHODS``.  ``vanilla``/``fused`` are exact;
        ``flash`` is exact; ``flash_bs`` is exact when beam_width >= K.
      parallelism, lanes, beam_width, chunk: as in `viterbi_decode`.
      bt: fused-kernel time-block size.

    Returns:
      (paths (B, T) int32, scores (B,)): paths[i, :lengths[i]] is the decode
      of emissions[i, :lengths[i]] (bit-identical to the unbatched call for
      exact methods); entries past the length repeat the final decoded state.
    """
    if method not in BATCH_METHODS:
        raise ValueError(
            f"unknown batch method {method!r}; choose from {BATCH_METHODS}")
    B, T, K = emissions.shape
    if lengths is None:
        lengths = jnp.full((B,), T, jnp.int32)
    lengths = jnp.clip(jnp.asarray(lengths, jnp.int32), 1, T)

    if T == 1:
        d0 = log_pi[None, :] + emissions[:, 0, :]
        q = jnp.argmax(d0, axis=1).astype(jnp.int32)
        return q[:, None], jnp.max(d0, axis=1)

    if method == "fused":
        from repro.kernels.ops import viterbi_decode_fused_batch
        return viterbi_decode_fused_batch(log_pi, log_A, emissions, lengths,
                                          bt=bt)

    pad = _pad_mask(T, lengths)
    if method == "vanilla":
        return _vanilla_batch(log_pi, log_A, emissions, pad)

    P = int(parallelism)
    if lanes == -1:
        lanes = P
    if method == "flash":
        return _flash_batch(log_pi, log_A, emissions, pad, P, lanes)
    return _flash_bs_batch(log_pi, log_A, emissions, pad, beam_width, P,
                           lanes, chunk)


__all__ = ["viterbi_decode_batch", "BATCH_METHODS"]
