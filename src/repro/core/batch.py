"""First-class batched decoding — `viterbi_decode_batch` over (B, T, K).

Batch-axis parallelism is where decoding throughput comes from on wide
hardware (cf. the GPU Viterbi literature): one launch amortises the
transition-matrix load and the dispatch overhead over the whole request
bucket.  This module is the single entry point serving goes through.

Ragged batches are the normal case in serving, so `lengths` is part of the
contract rather than an afterthought: sequence i is decoded *exactly* at
length `lengths[i]`, with the tail realised as tropical-identity pad steps
(stay in place, add 0 — the masking machinery shared with `flash.pad_emissions`
/ `flash._dp_step`, which provably leaves deltas, backpointers, and scores
unchanged).  Scores therefore contain no pad-transition mass and per-sequence
results are bit-identical to looped `viterbi_decode` calls for the exact
methods; `tests/test_batch.py` pins this.

Methods:
  * ``fused``    — batch-grid Pallas kernel (`kernels.ops.viterbi_decode_fused_batch`):
                   grid (B, T/bt), log_A resident in VMEM for the whole bucket.
  * ``vanilla``  — vmapped masked lax.scan (exact oracle).
  * ``flash``    — vmapped FLASH wavefront; ragged masks ride the same pad
                   machinery the algorithm already uses for its P·2^L padding.
  * ``flash_bs`` — vmapped FLASH-BS dynamic beam (exact when beam_width >= K).

Path entries at padded steps repeat the sequence's final decoded state
(identity backpointers); slice row i to [:lengths[i]] for the true path.

Multi-device: pass ``mesh=``/``data_axis=`` to shard the request bucket over
a mesh axis (`shard_map`, HMM tensors replicated, zero collectives — the
sequences are independent).  Per-sequence results are bit-identical to the
single-device decode; `tests/test_distributed.py` pins this on 8 virtual
devices.
"""

from __future__ import annotations

from functools import lru_cache

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec

from ..runtime.jaxcompat import shard_map
from .vanilla import viterbi_vanilla_masked
from .flash import plan_padding, _flash_padded
from .flash_bs import pad_state_space, _flash_bs_padded

BATCH_METHODS = ("vanilla", "flash", "flash_bs", "fused")


def _pad_mask(T: int, lengths: jax.Array) -> jax.Array:
    return jnp.arange(T)[None, :] >= lengths[:, None]    # (B, T) True == pad


def _validate_lengths(lengths: jax.Array, T: int) -> None:
    """Eagerly reject out-of-range lengths instead of silently clipping.

    Clipping (`jnp.clip(lengths, 1, T)`) used to paper over caller bugs — a
    0 or T+overrun length silently decoded the wrong frame span.  Concrete
    lengths are checked here; traced lengths (inside jit / shard_map) cannot
    be inspected, so out-of-range traced values are a caller contract
    violation with undefined results.
    """
    try:
        # flashlint: disable=FL002(eager pre-jit validation of host-side lengths metadata)
        conc = np.asarray(lengths)
    except (jax.errors.TracerArrayConversionError,
            jax.errors.ConcretizationTypeError):
        return
    if conc.size and (conc.min() < 1 or conc.max() > T):
        raise ValueError(
            f"lengths must lie in [1, T={T}]; got range "
            f"[{int(conc.min())}, {int(conc.max())}]")


def _vanilla_batch(log_pi, log_A, em, pad):
    return jax.vmap(
        lambda e, p: viterbi_vanilla_masked(log_pi, log_A, e, p))(em, pad)


def _flash_batch(log_pi, log_A, em, pad, P: int, lanes):
    B, T, K = em.shape
    Tp, _ = plan_padding(T, P)
    em_p = jnp.pad(em, ((0, 0), (0, Tp - T), (0, 0)))
    pad_p = jnp.pad(pad, ((0, 0), (0, Tp - T)), constant_values=True)
    q, s = jax.vmap(
        lambda e, p: _flash_padded(log_pi, log_A, e, p, P, lanes))(em_p, pad_p)
    return q[:, :T], s


def _flash_bs_batch(log_pi, log_A, em, pad, beam_width: int, P: int, lanes,
                    chunk: int):
    B, T, K = em.shape
    Bw = int(min(beam_width, K))
    chunk = int(min(chunk, K))
    log_pi, log_A, em, _ = pad_state_space(log_pi, log_A, em, chunk)
    Tp, _ = plan_padding(T, P)
    em_p = jnp.pad(em, ((0, 0), (0, Tp - T), (0, 0)))
    pad_p = jnp.pad(pad, ((0, 0), (0, Tp - T)), constant_values=True)
    q, s = jax.vmap(
        lambda e, p: _flash_bs_padded(log_pi, log_A, e, p, P, lanes, Bw,
                                      chunk))(em_p, pad_p)
    return q[:, :T], s


def viterbi_decode_batch(
    emissions: jax.Array,
    log_pi: jax.Array,
    log_A: jax.Array,
    lengths: jax.Array | None = None,
    method: str = "fused",
    *,
    parallelism: int = 8,
    lanes: int | None = -1,
    beam_width: int = 128,
    chunk: int = 128,
    bt: int = 8,
    mesh=None,
    data_axis: str = "data",
    constraint=None,
) -> tuple[jax.Array, jax.Array]:
    """Decode a (possibly ragged) batch of emission sequences.

    Args:
      emissions: (B, T, K) emission log-likelihoods, row i real for the first
        lengths[i] steps (pad frames may hold anything — they are masked).
      log_pi, log_A: shared HMM in log domain.
      lengths: optional (B,) int true lengths; None means every sequence is
        full-length.  Lengths are used *as given* — there is no clipping.
        Every concrete value must lie in [1, T] or a ValueError is raised
        eagerly; traced lengths (inside jit) cannot be checked and
        out-of-range values there are a contract violation with undefined
        results.
      method: one of ``BATCH_METHODS``.  ``vanilla``/``fused`` are exact;
        ``flash`` is exact; ``flash_bs`` is exact when beam_width >= K.
      parallelism, lanes, beam_width, chunk: as in `viterbi_decode`.
      bt: fused-kernel time-block size.
      mesh: optional `jax.sharding.Mesh`; when given, the batch axis is
        sharded over ``data_axis`` with `shard_map` (the axis size must
        divide B) and each device decodes its bucket slice with the exact
        same per-sequence compute — results stay bit-identical to the
        single-device call.  The HMM tensors are replicated.
      data_axis: mesh axis name the batch shards over.
      constraint: optional `core.constraints.ConstraintSpec`, shared by the
        whole bucket (per-step schedules index *absolute* step t, so ragged
        tails just never reach the later rows).  The local fused method keeps
        the inputs dense and fuses the penalty adds into the kernel; every
        other path (and the sharded one) pre-masks the inputs with
        `constrain_inputs` — both are bit-identical to decoding the
        pre-masked model.

    Returns:
      (paths (B, T) int32, scores (B,)): paths[i, :lengths[i]] is the decode
      of emissions[i, :lengths[i]] (bit-identical to the unbatched call for
      exact methods); entries past the length repeat the final decoded state.
    """
    if method not in BATCH_METHODS:
        raise ValueError(
            f"unknown batch method {method!r}; choose from {BATCH_METHODS}")
    B, T, K = emissions.shape
    if lengths is None:
        lengths = jnp.full((B,), T, jnp.int32)
    lengths = jnp.asarray(lengths, jnp.int32)
    _validate_lengths(lengths, T)

    if constraint is not None:
        from .constraints import compiled_penalties, constrain_inputs
        if method == "fused" and mesh is None and T > 1:
            from repro.kernels.ops import viterbi_decode_fused_batch_masked
            t_pen, pi_pen, s_pen = compiled_penalties(constraint, K, T)
            return viterbi_decode_fused_batch_masked(
                log_pi, log_A, emissions, lengths,
                t_pen=t_pen, pi_pen=pi_pen, s_pen=s_pen, bt=bt)
        log_pi, log_A, emissions = constrain_inputs(
            constraint, log_pi, log_A, emissions)

    if T == 1:
        d0 = log_pi[None, :] + emissions[:, 0, :]
        q = jnp.argmax(d0, axis=1).astype(jnp.int32)
        return q[:, None], jnp.max(d0, axis=1)

    if mesh is not None:
        return _sharded_batch(emissions, log_pi, log_A, lengths, method,
                              mesh=mesh, data_axis=data_axis,
                              parallelism=parallelism, lanes=lanes,
                              beam_width=beam_width, chunk=chunk, bt=bt)

    if method == "fused":
        from repro.kernels.ops import viterbi_decode_fused_batch
        return viterbi_decode_fused_batch(log_pi, log_A, emissions, lengths,
                                          bt=bt)

    pad = _pad_mask(T, lengths)
    if method == "vanilla":
        return _vanilla_batch(log_pi, log_A, emissions, pad)

    P = int(parallelism)
    if lanes == -1:
        lanes = P
    if method == "flash":
        return _flash_batch(log_pi, log_A, emissions, pad, P, lanes)
    return _flash_bs_batch(log_pi, log_A, emissions, pad, beam_width, P,
                           lanes, chunk)


@lru_cache(maxsize=64)
def _sharded_decoder(mesh, data_axis, method, kw_items):
    """Build (and cache) the jitted shard_map-ed decoder for one config.

    Cached + jitted so repeated eager `mesh=` calls reuse one compiled
    callable — jit's cache keys on callable identity, and a fresh shard_map
    closure per call would retrace (and recompile) every time.
    """
    kw = dict(kw_items)
    Ps = PartitionSpec

    def _local(lp, la, em, ln):
        return viterbi_decode_batch(em, lp, la, ln, method=method, **kw)

    return jax.jit(shard_map(
        _local, mesh=mesh,
        in_specs=(Ps(), Ps(), Ps(data_axis, None, None), Ps(data_axis)),
        out_specs=(Ps(data_axis, None), Ps(data_axis)),
        check_replication=False))


def _sharded_batch(emissions, log_pi, log_A, lengths, method, *, mesh,
                   data_axis, **kw):
    """Shard the request bucket over `data_axis` and decode per device.

    Sequences are independent, so the shard_map body is just the
    single-device `viterbi_decode_batch` on the local (B/dp, T, K) slice —
    no collectives, and per-sequence results are bit-identical to the
    unsharded call (vmap lanes never interact).  log_pi/log_A replicate.
    """
    dp = mesh.shape[data_axis]
    B = emissions.shape[0]
    if B % dp:
        raise ValueError(
            f"mesh axis {data_axis!r}={dp} must divide batch size {B}; pad "
            f"the bucket with length-1 dummies (serving.alignment does this)")
    sharded = _sharded_decoder(mesh, data_axis, method,
                               tuple(sorted(kw.items())))
    return sharded(log_pi, log_A, emissions, lengths)


__all__ = ["viterbi_decode_batch", "BATCH_METHODS"]
