"""Typed decode specs — the configuration objects behind every decoder.

A `DecodeSpec` is a frozen, hashable dataclass that pins *one* point on the
paper's time/memory trade-off curve: the algorithm plus exactly the tunables
that algorithm consumes.  Nonsense is rejected eagerly (`beam_width=0` raises
at construction, an unknown tunable raises `TypeError` from the dataclass
constructor) instead of being silently dropped the way the legacy
string+kwargs dispatch did.

Specs are the currency of the whole call graph above the kernels:

    spec = FlashSpec(parallelism=8)            # or planner.plan(...).spec
    path, score = spec.run(log_pi, log_A, em)  # one sequence, eager
    dec = ViterbiDecoder(spec, log_pi, log_A)  # jit-cached decoder object

Hashability is load-bearing: a spec is a jit-cache / plan-cache key, so every
field is a scalar or None and the dataclasses are `frozen=True`.

`ResourceBudget` is the *input* to the planner (`core/planner.py`): how much
memory the deployment grants the live DP state, and which way to lean when
several specs fit.
"""

from __future__ import annotations

import dataclasses
from typing import Any, ClassVar, Mapping, Optional

from .constraints import ConstraintSpec, constrain_inputs

__all__ = [
    "ResourceBudget", "DecodeSpec",
    "VanillaSpec", "CheckpointSpec", "FlashSpec", "FlashBSSpec",
    "BeamStaticSpec", "BeamStaticMPSpec", "AssocSpec", "FusedSpec",
    "OnlineSpec", "OnlineBeamSpec",
    "SPEC_BY_METHOD", "spec_from_tunables", "as_decode_spec",
]


def _check(cond: bool, msg: str) -> None:
    if not cond:
        raise ValueError(msg)


def _check_pos(value: Any, name: str) -> None:
    _check(isinstance(value, int) and not isinstance(value, bool)
           and value >= 1, f"{name} must be an int >= 1, got {value!r}")


def _check_lanes(lanes: Any) -> None:
    """lanes: None = vectorise whole layers, -1 = match parallelism, n >= 1."""
    if lanes is None or lanes == -1:
        return
    _check_pos(lanes, "lanes")


def _check_opt_pos(value: Any, name: str) -> None:
    if value is not None:
        _check_pos(value, name)


@dataclasses.dataclass(frozen=True)
class ResourceBudget:
    """Deployment resource envelope handed to the planner.

    memory_bytes: cap on *live decoder-state bytes* (the quantity the paper's
      Fig. 1/7/9 track — see `planner.decoder_state_bytes`); None = unlimited.
    latency_hint: which way to lean among configs that fit —
      "latency" (default: exact decode with the largest parallelism that
      fits) or "memory" (smallest-footprint exact config, headroom be damned).
    """
    memory_bytes: int | None = None
    latency_hint: str | None = None

    def __post_init__(self):
        if self.memory_bytes is not None:
            _check_pos(self.memory_bytes, "memory_bytes")
        _check(self.latency_hint in (None, "latency", "memory"),
               f"latency_hint must be None, 'latency' or 'memory', "
               f"got {self.latency_hint!r}")


@dataclasses.dataclass(frozen=True)
class DecodeSpec:
    """Base class: one decoding algorithm + its (validated) tunables.

    Subclasses set the class-level contract:
      method          — the legacy `viterbi_decode(method=...)` string.
      batch_method    — name in `core.batch.BATCH_METHODS`, or None if the
                        algorithm has no batched path.
      jittable        — whether `run` may be wrapped in one `jax.jit`
                        (the streaming decoders are stateful Python, so no).
      legacy_tunables — legacy `viterbi_decode` kwarg name -> field name map;
                        anything *not* listed here is ignored-with-a-warning
                        by the back-compat shim and rejected by the spec.

    Every spec additionally carries an optional `constraint`
    (`core.constraints.ConstraintSpec`): a frozen, hashable description of
    which states/transitions are legal.  `run` applies it by masking the
    inputs with tropical-identity adds (`constrain_inputs`), so a constrained
    decode is bit-identical to the same method over the pre-masked model;
    specs with a fused kernel path override `_run_constrained` to apply the
    same adds inside the kernel instead of materialising masked inputs.
    """
    method: ClassVar[str] = ""
    batch_method: ClassVar[str | None] = None
    jittable: ClassVar[bool] = True
    legacy_tunables: ClassVar[Mapping[str, str]] = {}
    constraint: Optional[ConstraintSpec] = dataclasses.field(
        default=None, kw_only=True)

    def __post_init__(self):
        if self.constraint is not None and \
                not isinstance(self.constraint, ConstraintSpec):
            raise TypeError(f"constraint must be a ConstraintSpec or None, "
                            f"got {type(self.constraint).__name__}")
        self.validate()

    def validate(self) -> None:
        """Eager validation; subclasses raise ValueError on nonsense."""

    def run(self, log_pi, log_A, emissions):
        """Decode one (T, K) sequence; returns (path (T,) int32, score)."""
        if self.constraint is None:
            return self._run(log_pi, log_A, emissions)
        return self._run_constrained(log_pi, log_A, emissions,
                                     self.constraint)

    def _run(self, log_pi, log_A, emissions):
        """The unconstrained decode; what subclasses implement."""
        raise NotImplementedError

    def _run_constrained(self, log_pi, log_A, emissions, constraint):
        """Constrained decode; default = the method over pre-masked inputs."""
        return self._run(*constrain_inputs(constraint, log_pi, log_A,
                                           emissions))

    def batch_tunables(self) -> dict[str, Any]:
        """Tunables forwarded to `viterbi_decode_batch` (batchable specs)."""
        return {}


@dataclasses.dataclass(frozen=True)
class VanillaSpec(DecodeSpec):
    """Textbook DP with the full backpointer table — the exact oracle."""
    method: ClassVar[str] = "vanilla"
    batch_method: ClassVar[str | None] = "vanilla"

    def _run(self, log_pi, log_A, emissions):
        from .vanilla import viterbi_vanilla
        return viterbi_vanilla(log_pi, log_A, emissions)


@dataclasses.dataclass(frozen=True)
class CheckpointSpec(DecodeSpec):
    """Tarnas–Hughey checkpointing; seg_len=None means ceil(sqrt(T))."""
    method: ClassVar[str] = "checkpoint"
    legacy_tunables: ClassVar[Mapping[str, str]] = {"seg_len": "seg_len"}
    seg_len: int | None = None

    def validate(self):
        _check_opt_pos(self.seg_len, "seg_len")

    def _run(self, log_pi, log_A, emissions):
        from .checkpoint_viterbi import viterbi_checkpoint
        return viterbi_checkpoint(log_pi, log_A, emissions,
                                  seg_len=self.seg_len)


@dataclasses.dataclass(frozen=True)
class FlashSpec(DecodeSpec):
    """The paper's non-recursive divide-and-conquer wavefront (exact)."""
    method: ClassVar[str] = "flash"
    batch_method: ClassVar[str | None] = "flash"
    legacy_tunables: ClassVar[Mapping[str, str]] = {
        "parallelism": "parallelism", "lanes": "lanes"}
    parallelism: int = 8
    lanes: int | None = -1

    def validate(self):
        _check_pos(self.parallelism, "parallelism")
        _check_lanes(self.lanes)

    def _run(self, log_pi, log_A, emissions):
        from .flash import flash_viterbi
        return flash_viterbi(log_pi, log_A, emissions,
                             parallelism=self.parallelism, lanes=self.lanes)

    def batch_tunables(self):
        return {"parallelism": self.parallelism, "lanes": self.lanes}


@dataclasses.dataclass(frozen=True)
class FlashBSSpec(DecodeSpec):
    """FLASH with the dynamic top-B beam (exact when beam_width >= K)."""
    method: ClassVar[str] = "flash_bs"
    batch_method: ClassVar[str | None] = "flash_bs"
    legacy_tunables: ClassVar[Mapping[str, str]] = {
        "beam_width": "beam_width", "parallelism": "parallelism",
        "lanes": "lanes", "chunk": "chunk"}
    beam_width: int = 128
    parallelism: int = 8
    lanes: int | None = -1
    chunk: int = 128

    def validate(self):
        _check_pos(self.beam_width, "beam_width")
        _check_pos(self.parallelism, "parallelism")
        _check_lanes(self.lanes)
        _check_pos(self.chunk, "chunk")

    def _run(self, log_pi, log_A, emissions):
        from .flash_bs import flash_bs_viterbi
        return flash_bs_viterbi(log_pi, log_A, emissions,
                                beam_width=self.beam_width,
                                parallelism=self.parallelism,
                                lanes=self.lanes, chunk=self.chunk)

    def batch_tunables(self):
        return {"beam_width": self.beam_width,
                "parallelism": self.parallelism,
                "lanes": self.lanes, "chunk": self.chunk}


@dataclasses.dataclass(frozen=True)
class BeamStaticSpec(DecodeSpec):
    """Static beam baseline (scores all K, then truncates to the beam)."""
    method: ClassVar[str] = "beam_static"
    legacy_tunables: ClassVar[Mapping[str, str]] = {"beam_width": "beam_width"}
    beam_width: int = 128

    def validate(self):
        _check_pos(self.beam_width, "beam_width")

    def _run(self, log_pi, log_A, emissions):
        from .beam_static import beam_static_viterbi
        return beam_static_viterbi(log_pi, log_A, emissions,
                                   B=min(self.beam_width,
                                         emissions.shape[1]))


@dataclasses.dataclass(frozen=True)
class BeamStaticMPSpec(DecodeSpec):
    """Static beam on the multi-partition FLASH wavefront."""
    method: ClassVar[str] = "beam_static_mp"
    legacy_tunables: ClassVar[Mapping[str, str]] = {
        "beam_width": "beam_width", "parallelism": "parallelism",
        "lanes": "lanes"}
    beam_width: int = 128
    parallelism: int = 8
    lanes: int | None = -1

    def validate(self):
        _check_pos(self.beam_width, "beam_width")
        _check_pos(self.parallelism, "parallelism")
        _check_lanes(self.lanes)

    def _run(self, log_pi, log_A, emissions):
        from .beam_static import beam_static_mp_viterbi
        return beam_static_mp_viterbi(log_pi, log_A, emissions,
                                      beam_width=self.beam_width,
                                      parallelism=self.parallelism,
                                      lanes=self.lanes)


@dataclasses.dataclass(frozen=True)
class AssocSpec(DecodeSpec):
    """Tropical associative scan — O(log T) depth, O(K^3 T) work."""
    method: ClassVar[str] = "assoc"

    def _run(self, log_pi, log_A, emissions):
        from .assoc import viterbi_assoc
        return viterbi_assoc(log_pi, log_A, emissions)


@dataclasses.dataclass(frozen=True)
class FusedSpec(DecodeSpec):
    """Fused Pallas forward kernel (log_A VMEM-resident) + XLA backtrack.

    `bt` is the time-block size of the batch-grid kernel; the single-sequence
    path picks its own tiling.
    """
    method: ClassVar[str] = "fused"
    batch_method: ClassVar[str | None] = "fused"
    legacy_tunables: ClassVar[Mapping[str, str]] = {"bt": "bt"}
    bt: int = 8

    def validate(self):
        _check_pos(self.bt, "bt")

    def _run(self, log_pi, log_A, emissions):
        from repro.kernels.ops import viterbi_decode_fused
        return viterbi_decode_fused(log_pi, log_A, emissions)

    def _run_constrained(self, log_pi, log_A, emissions, constraint):
        # The fused path applies constraints *inside* the kernel: a
        # BandConstraint that covers the horizon decodes over sliding
        # windows (never materialising K-wide rows), anything else fuses the
        # penalty adds into the DP step.  Both reproduce the masked-input
        # adds operand-for-operand, so results stay bit-identical to the
        # generic path.
        from .constraints import compiled_penalties
        from repro.kernels.ops import (viterbi_decode_banded,
                                       viterbi_decode_fused_masked)
        T = emissions.shape[0]
        band = constraint.band()
        if band is not None and len(band[0]) >= T:
            centers, width = band
            return viterbi_decode_banded(log_pi, log_A, emissions,
                                         centers[:T], width=width)
        K = log_A.shape[-1]
        t_pen, pi_pen, s_pen = compiled_penalties(constraint, K, T)
        return viterbi_decode_fused_masked(log_pi, log_A, emissions,
                                           t_pen=t_pen, pi_pen=pi_pen,
                                           s_pen=s_pen)

    def batch_tunables(self):
        return {"bt": self.bt}


@dataclasses.dataclass(frozen=True)
class OnlineSpec(DecodeSpec):
    """Streaming exact decode (convergence-point commits), one-shot form.

    `stream_chunk` is the chunk size the one-shot `run` feeds with; `max_lag`
    bounds commit latency (forced flushes make the forced part approximate).
    For true incremental use build the decoder via `make_streaming`.
    """
    method: ClassVar[str] = "online"
    jittable: ClassVar[bool] = False
    legacy_tunables: ClassVar[Mapping[str, str]] = {
        "stream_chunk": "stream_chunk", "max_lag": "max_lag"}
    stream_chunk: int = 64
    max_lag: int | None = None

    def validate(self):
        _check_pos(self.stream_chunk, "stream_chunk")
        _check_opt_pos(self.max_lag, "max_lag")

    def _run(self, log_pi, log_A, emissions):
        from .online import viterbi_online
        return viterbi_online(log_pi, log_A, emissions,
                              chunk_size=self.stream_chunk,
                              max_lag=self.max_lag)

    def make_streaming(self, log_pi, log_A):
        """The stateful incremental decoder `serving.stream` wraps."""
        from .online import OnlineViterbiDecoder
        return OnlineViterbiDecoder(log_pi, log_A, max_lag=self.max_lag,
                                    constraint=self.constraint)


@dataclasses.dataclass(frozen=True)
class OnlineBeamSpec(DecodeSpec):
    """Streaming dynamic beam — live state O(W*B), K never materialises."""
    method: ClassVar[str] = "online_beam"
    jittable: ClassVar[bool] = False
    legacy_tunables: ClassVar[Mapping[str, str]] = {
        "beam_width": "beam_width", "chunk": "kchunk",
        "stream_chunk": "stream_chunk", "max_lag": "max_lag"}
    beam_width: int = 128
    kchunk: int = 128
    stream_chunk: int = 64
    max_lag: int | None = None

    def validate(self):
        _check_pos(self.beam_width, "beam_width")
        _check_pos(self.kchunk, "kchunk")
        _check_pos(self.stream_chunk, "stream_chunk")
        _check_opt_pos(self.max_lag, "max_lag")

    def _run(self, log_pi, log_A, emissions):
        from .online import viterbi_online_beam
        return viterbi_online_beam(log_pi, log_A, emissions,
                                   beam_width=self.beam_width,
                                   kchunk=self.kchunk,
                                   chunk_size=self.stream_chunk,
                                   max_lag=self.max_lag)

    def make_streaming(self, log_pi, log_A):
        from .online import OnlineBeamDecoder
        return OnlineBeamDecoder(log_pi, log_A, beam_width=self.beam_width,
                                 kchunk=self.kchunk, max_lag=self.max_lag,
                                 constraint=self.constraint)


SPEC_BY_METHOD: dict[str, type[DecodeSpec]] = {
    cls.method: cls for cls in (
        VanillaSpec, CheckpointSpec, FlashSpec, FlashBSSpec,
        BeamStaticSpec, BeamStaticMPSpec, AssocSpec, FusedSpec,
        OnlineSpec, OnlineBeamSpec)
}


def spec_from_tunables(method: str, tunables: dict[str, Any],
                       ) -> tuple[DecodeSpec, tuple[str, ...]]:
    """Build the spec for a legacy (method, kwargs) call.

    Returns (spec, ignored): `ignored` names the tunables `method` does not
    consume — the back-compat `viterbi_decode` shim turns those into a
    DeprecationWarning instead of the old silent drop.
    """
    if "constraint" in tunables:
        # never let the legacy shim silently decode unconstrained: the old
        # dispatch's ignore-with-a-warning policy would be a correctness bug
        # here, not a deprecation nit.
        raise TypeError(
            "constraint= is not a legacy tunable; construct a typed spec "
            "instead, e.g. FusedSpec(constraint=...) or "
            "with_constraint(spec, constraint)")
    try:
        cls = SPEC_BY_METHOD[method]
    except KeyError:
        raise ValueError(f"unknown method {method!r}; choose from "
                         f"{tuple(SPEC_BY_METHOD)}") from None
    fields: dict[str, Any] = {}
    ignored: list[str] = []
    for name, value in tunables.items():
        target = cls.legacy_tunables.get(name)
        if target is None:
            ignored.append(name)
        else:
            fields[target] = value
    return cls(**fields), tuple(ignored)


def as_decode_spec(obj: Any) -> DecodeSpec:
    """Coerce a spec-like object (spec, or anything with `.to_spec()`)."""
    if isinstance(obj, DecodeSpec):
        return obj
    to_spec = getattr(obj, "to_spec", None)
    if callable(to_spec):
        return to_spec()
    raise TypeError(f"expected a DecodeSpec (or an object with .to_spec()), "
                    f"got {type(obj).__name__}")
