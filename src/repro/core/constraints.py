"""Constrained decoding: frozen `ConstraintSpec`s compiled to penalty masks.

The paper's headline applications — map matching and forced alignment — are
*constrained* Viterbi problems: only a structured subset of states/transitions
is legal at each step.  FLCVA (PAPERS.md, cs/0601108) fuses such constraints
into the recurrence so speed and memory improve together.  This module is the
constraint half of that story; the kernels (`repro.kernels.ops`) are the other.

A `ConstraintSpec` is a frozen, hashable dataclass — like a `DecodeSpec`, it
is a jit-cache key.  Every spec compiles (host-side, cached) to up to three
additive f32 penalty arrays whose entries are exactly ``0.0`` or ``NEG_INF``:

    t_pen  (K, K)  transition penalty, added to `log_A`
    pi_pen (K,)    initial-state penalty, added to `log_pi`
    s_pen  (T, K)  per-step state penalty, added to the emissions

Masking is *always* expressed as these adds (tropical-identity adds: adding
``0.0`` keeps a score, adding ``NEG_INF`` kills it).  Because every consumer —
the dense reference, the fused Pallas kernel, the banded fast path and the
streaming decoders — applies the same float adds to the same operands, a
constrained decode is bit-identical to an unconstrained decode over the
pre-masked inputs (`constrain_inputs`).  That identity is the oracle the
tests pin.

Infeasibility is eager: an all-masked step raises `ValueError` at constraint
construction (empty anchor) or at compile time (reachability walk finds an
empty live set), never NaN scores at decode time.

Compiled penalties are numpy arrays so they become jit-constants; the caches
are keyed by the (hashable) constraint, so equal constraints share compiles
exactly like equal `DecodeSpec`s share jit entries.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import numpy as np

from .hmm import NEG_INF

__all__ = [
    "ConstraintSpec", "TransitionMaskConstraint", "BandConstraint",
    "LexiconConstraint", "ScheduleConstraint",
    "transition_penalty", "init_penalty", "step_penalty",
    "step_penalty_rows", "compiled_penalties", "constrain_inputs",
    "with_constraint", "banded_state_bytes",
]


def _check(cond: bool, msg: str) -> None:
    if not cond:
        raise ValueError(msg)


def _int_tuple(values: Any, name: str) -> tuple:
    try:
        out = tuple(int(v) for v in values)
    except TypeError:
        raise ValueError(f"{name} must be an iterable of ints, "
                         f"got {values!r}") from None
    _check(all(v >= 0 for v in out), f"{name} entries must be >= 0")
    return out


@dataclasses.dataclass(frozen=True)
class ConstraintSpec:
    """Base class: a hashable description of which states/transitions are legal.

    Subclasses implement the private compile hooks below; the public compiled
    surface (`transition_penalty` / `step_penalty` / `constrain_inputs`) is
    shared and cached.  The hooks are host-side numpy — constraints compile to
    constants, they are never traced.
    """

    def validate(self) -> None:
        """Eager structural validation; raise ValueError on nonsense."""

    def __post_init__(self):
        self.validate()

    # ---- compile hooks (None = unconstrained along that axis) -------------

    def _transition_allowed(self, K: int) -> Optional[np.ndarray]:
        """(K, K) bool, [i, j] True iff i -> j is legal; None = all legal."""
        return None

    def _init_allowed(self, K: int) -> Optional[np.ndarray]:
        """(K,) bool of legal initial states; None = all legal."""
        return None

    def _step_allowed(self, K: int, t: int) -> Optional[np.ndarray]:
        """(K,) bool of states legal at step t; None = all legal.

        Steps beyond a constraint's horizon (e.g. past the last band center)
        are unconstrained and must return None here.
        """
        return None

    def _has_step_component(self) -> bool:
        """Whether a per-step `s_pen` exists at all.

        Must be constant per constraint (not per step): the streaming decoders
        use it to decide whether to add penalty rows chunk-by-chunk, and the
        decision has to match the offline `s_pen is None` choice bit-for-bit.
        """
        return False

    def _schedule_from_reachability(self) -> bool:
        """Whether `s_pen` rows are the reachability walk's live sets.

        Lexicon constraints compile their trie into per-step allowed-state
        sets this way; pure transition masks only use the walk to prove
        feasibility.
        """
        return False

    # ---- planner surface --------------------------------------------------

    def band(self) -> Optional[tuple[tuple[int, ...], int]]:
        """(centers, width) when this is a banded constraint, else None."""
        return None

    def live_states(self, K: int) -> int:
        """Upper bound on states simultaneously live under this constraint."""
        return K

    def mask_bytes(self, K: int, T: int) -> int:
        """Bytes of compiled penalty arrays the generic masked path holds."""
        n = 0
        if self._transition_allowed(K) is not None:
            n += K * K * 4
        if self._init_allowed(K) is not None:
            n += K * 4
        if self._has_step_component():
            n += T * K * 4
        return n


@dataclasses.dataclass(frozen=True)
class TransitionMaskConstraint(ConstraintSpec):
    """Static allowed-transition mask: only the listed (src, dst) arcs are legal.

    `init_states=None` leaves the initial distribution unconstrained.  The
    compile-time reachability walk rejects dead ends eagerly: if after some
    step no state with an outgoing arc is live, `ValueError` is raised at
    compile, not NaN at decode.
    """
    edges: tuple[tuple[int, int], ...]
    init_states: Optional[tuple[int, ...]] = None

    def validate(self):
        _check(len(self.edges) >= 1, "edges must be non-empty")
        object.__setattr__(self, "edges", tuple(
            (int(s), int(d)) for s, d in self.edges))
        _check(all(s >= 0 and d >= 0 for s, d in self.edges),
               "edge endpoints must be >= 0")
        if self.init_states is not None:
            object.__setattr__(self, "init_states",
                               _int_tuple(self.init_states, "init_states"))
            _check(len(self.init_states) >= 1,
                   "init_states must be non-empty (an empty initial set "
                   "masks every path)")

    def _transition_allowed(self, K):
        hi = max(max(s, d) for s, d in self.edges)
        _check(hi < K, f"edge endpoint {hi} out of range for K={K}")
        allowed = np.zeros((K, K), dtype=bool)
        for s, d in self.edges:
            allowed[s, d] = True
        return allowed

    def _init_allowed(self, K):
        if self.init_states is None:
            return None
        _check(max(self.init_states) < K,
               f"init state {max(self.init_states)} out of range for K={K}")
        allowed = np.zeros(K, dtype=bool)
        allowed[list(self.init_states)] = True
        return allowed

    def live_states(self, K):
        states = {s for e in self.edges for s in e}
        states.update(self.init_states or ())
        return min(len(states), K)


@dataclasses.dataclass(frozen=True)
class BandConstraint(ConstraintSpec):
    """Banded reachability: at step t only states within `width` of
    `centers[t]` are legal (map matching: the road cells near observation t).

    Centers are clipped into [0, K-1] at compile; steps past the centers
    horizon are unconstrained.  `FusedSpec` decodes this without ever
    materialising K-wide rows (O(T * Kb^2) work, Kb = 2*width+1); every other
    method applies it as a per-step penalty.  Both are bit-identical to the
    dense masked decode *when the in-band states keep feasible paths* (dense
    `log_A`) — with a sparse `log_A`, compose with `TransitionMaskConstraint`
    semantics by pre-masking `log_A` instead.
    """
    centers: tuple[int, ...]
    width: int

    def validate(self):
        object.__setattr__(self, "centers",
                           _int_tuple(self.centers, "centers"))
        _check(len(self.centers) >= 1, "centers must be non-empty")
        _check(isinstance(self.width, int) and not isinstance(self.width, bool)
               and self.width >= 0,
               f"width must be an int >= 0, got {self.width!r}")

    def _step_allowed(self, K, t):
        if t >= len(self.centers):
            return None
        c = min(max(self.centers[t], 0), K - 1)
        idx = np.arange(K)
        return np.abs(idx - c) <= self.width

    def _has_step_component(self):
        return True

    def band(self):
        return self.centers, self.width

    def live_states(self, K):
        return min(2 * self.width + 1, K)


@dataclasses.dataclass(frozen=True)
class LexiconConstraint(ConstraintSpec):
    """Word/pronunciation trie compiled into per-step allowed-state sets.

    `words[w]` is a tuple of pronunciation *alternatives*; each alternative is
    the state sequence of that pronunciation.  Legal arcs are succession
    within an alternative, optional state self-loops (frame-level dwell,
    `self_loops`) and pronunciation-final -> pronunciation-initial arcs for
    connected word sequences (`loop_words`).  Decoding may start at any
    pronunciation-initial state.

    The per-step allowed sets are the reachability walk's live sets, so the
    compiled `s_pen` encodes exactly "states reachable from some word start
    in t legal arcs" — the FLCVA-style lexical schedule.
    """
    words: tuple[tuple[tuple[int, ...], ...], ...]
    self_loops: bool = True
    loop_words: bool = True

    def validate(self):
        _check(len(self.words) >= 1, "words must be non-empty")
        norm = []
        for w, prons in enumerate(self.words):
            _check(len(prons) >= 1,
                   f"word {w} needs at least one pronunciation")
            norm.append(tuple(_int_tuple(p, f"words[{w}] pronunciation")
                              for p in prons))
            _check(all(len(p) >= 1 for p in norm[-1]),
                   f"word {w} has an empty pronunciation")
        object.__setattr__(self, "words", tuple(norm))

    def _states(self) -> set[int]:
        return {s for prons in self.words for p in prons for s in p}

    def _transition_allowed(self, K):
        hi = max(self._states())
        _check(hi < K, f"lexicon state {hi} out of range for K={K}")
        allowed = np.zeros((K, K), dtype=bool)
        finals, initials = [], []
        for prons in self.words:
            for p in prons:
                initials.append(p[0])
                finals.append(p[-1])
                for a, b in zip(p[:-1], p[1:]):
                    allowed[a, b] = True
        if self.self_loops:
            for s in self._states():
                allowed[s, s] = True
        if self.loop_words:
            for f in finals:
                for i in initials:
                    allowed[f, i] = True
        return allowed

    def _init_allowed(self, K):
        allowed = np.zeros(K, dtype=bool)
        allowed[[p[0] for prons in self.words for p in prons]] = True
        return allowed

    def _has_step_component(self):
        return True

    def _schedule_from_reachability(self):
        return True

    def live_states(self, K):
        return min(len(self._states()), K)


@dataclasses.dataclass(frozen=True)
class ScheduleConstraint(ConstraintSpec):
    """Time-varying mask: at each anchored step only the listed states are
    legal (forced-alignment anchors).  Unanchored steps are unconstrained.

    An empty anchor set would mask the whole step, so it raises here —
    eagerly, at construction.
    """
    anchors: tuple[tuple[int, tuple[int, ...]], ...]

    def validate(self):
        _check(len(self.anchors) >= 1, "anchors must be non-empty")
        norm = []
        for t, states in self.anchors:
            t = int(t)
            _check(t >= 0, f"anchor step {t} must be >= 0")
            states = _int_tuple(states, f"anchor[{t}] states")
            _check(len(states) >= 1,
                   f"anchor at step {t} has an empty state set: every path "
                   f"through step {t} would be masked")
            norm.append((t, states))
        steps = [t for t, _ in norm]
        _check(len(set(steps)) == len(steps), "duplicate anchor steps")
        object.__setattr__(self, "anchors", tuple(norm))

    def _anchor_map(self) -> dict[int, tuple[int, ...]]:
        return dict(self.anchors)

    def _step_allowed(self, K, t):
        states = self._anchor_map().get(t)
        if states is None:
            return None
        _check(max(states) < K,
               f"anchor state {max(states)} out of range for K={K}")
        allowed = np.zeros(K, dtype=bool)
        allowed[list(states)] = True
        return allowed

    def _has_step_component(self):
        return True


# --------------------------------------------------------------------------
# Compilation: constraint -> numpy penalty constants (cached, feasibility-
# checked).  Penalties are additive and exactly {0.0, NEG_INF} in f32.
# --------------------------------------------------------------------------


def _penalty(allowed: np.ndarray) -> np.ndarray:
    out = np.zeros(allowed.shape, dtype=np.float32)
    out[~allowed] = np.float32(NEG_INF)
    return out


@functools.lru_cache(maxsize=512)
def transition_penalty(constraint: ConstraintSpec,
                       K: int) -> Optional[np.ndarray]:
    """(K, K) f32 penalty for `log_A`, or None when transitions are free."""
    allowed = constraint._transition_allowed(K)
    return None if allowed is None else _penalty(allowed)


@functools.lru_cache(maxsize=512)
def init_penalty(constraint: ConstraintSpec, K: int) -> Optional[np.ndarray]:
    """(K,) f32 penalty for `log_pi`, or None when the start is free."""
    allowed = constraint._init_allowed(K)
    return None if allowed is None else _penalty(allowed)


class _ReachWalker:
    """Incremental reachability walk R_t over a constraint's allowed sets.

    R_0 = init ∩ allowed(0); R_t = succ(R_{t-1}) ∩ allowed(t).  Rows are
    cached so streaming decoders can ask for step t without recomputing the
    prefix, and a fixpoint (R_{t+1} == R_t with no step mask ahead) stops the
    walk — the common self-loop lexicon converges in a handful of steps.
    Raises ValueError the moment a step's live set is empty.
    """

    def __init__(self, constraint: ConstraintSpec, K: int):
        self.c = constraint
        self.K = K
        self.ta = constraint._transition_allowed(K)
        init = constraint._init_allowed(K)
        r0 = np.ones(K, dtype=bool) if init is None else init.copy()
        sa0 = constraint._step_allowed(K, 0)
        if sa0 is not None:
            r0 &= sa0
        self.rows: list[np.ndarray] = [r0]
        self.fixpoint: Optional[int] = None
        self._raise_if_empty(r0, 0)

    def _raise_if_empty(self, row: np.ndarray, t: int) -> None:
        if not row.any():
            raise ValueError(
                f"infeasible constraint {type(self.c).__name__}: no legal "
                f"state is reachable at step {t} (every path is masked)")

    def row(self, t: int) -> np.ndarray:
        if self.fixpoint is not None and t >= self.fixpoint:
            return self.rows[self.fixpoint]
        while len(self.rows) <= t:
            prev = self.rows[-1]
            tn = len(self.rows)
            if self.ta is None:
                nxt = np.ones(self.K, dtype=bool)
            else:
                nxt = self.ta[prev, :].any(axis=0)
            sa = self.c._step_allowed(self.K, tn)
            if sa is not None:
                nxt &= sa
            self._raise_if_empty(nxt, tn)
            if sa is None and np.array_equal(nxt, prev):
                # no time-varying mask ahead of a converged set for *this*
                # step; only safe as a terminal fixpoint when the constraint
                # has no step masks at all beyond here — band/schedule rows
                # can re-shrink, so only reachability-scheduled or maskless
                # constraints may stop early.
                if self.c._schedule_from_reachability() or \
                        not self.c._has_step_component():
                    self.fixpoint = tn
                    self.rows.append(nxt)
                    return nxt
            self.rows.append(nxt)
        return self.rows[t]


_WALKERS: dict[tuple[ConstraintSpec, int], _ReachWalker] = {}


def _walker(constraint: ConstraintSpec, K: int) -> _ReachWalker:
    key = (constraint, K)
    w = _WALKERS.get(key)
    if w is None:
        w = _ReachWalker(constraint, K)
        _WALKERS[key] = w
    return w


def _step_row_allowed(constraint: ConstraintSpec, K: int,
                      t: int) -> np.ndarray:
    """The (K,) bool allowed set the compiled `s_pen` row t encodes."""
    if constraint._schedule_from_reachability():
        return _walker(constraint, K).row(t)
    sa = constraint._step_allowed(K, t)
    return np.ones(K, dtype=bool) if sa is None else sa


@functools.lru_cache(maxsize=256)
def step_penalty(constraint: ConstraintSpec, K: int,
                 T: int) -> Optional[np.ndarray]:
    """(T, K) f32 per-step penalty, or None when no step component exists.

    Compiling also proves feasibility over the horizon: the reachability walk
    (init set pushed through the allowed arcs, intersected with each step's
    allowed set) must stay non-empty for T steps, else ValueError.
    """
    walker = _walker(constraint, K)
    for t in range(T):
        walker.row(t)                       # feasibility over the horizon
    if not constraint._has_step_component():
        return None
    out = np.zeros((T, K), dtype=np.float32)
    for t in range(T):
        out[t] = _penalty(_step_row_allowed(constraint, K, t))
    return out


def step_penalty_rows(constraint: ConstraintSpec, K: int, t0: int,
                      n: int) -> Optional[np.ndarray]:
    """Rows [t0, t0+n) of the step penalty, for streaming decoders.

    Returns None when the constraint has no step component (matching the
    offline `step_penalty` None-ness, so streaming and offline apply exactly
    the same float adds).  Rows beyond a constraint's horizon are zeros.
    """
    if not constraint._has_step_component():
        _walker(constraint, K)              # still eager-check step 0
        return None
    out = np.zeros((n, K), dtype=np.float32)
    for i in range(n):
        out[i] = _penalty(_step_row_allowed(constraint, K, t0 + i))
    return out


def compiled_penalties(constraint: ConstraintSpec, K: int, T: int,
                       ) -> tuple[Optional[np.ndarray], Optional[np.ndarray],
                                  Optional[np.ndarray]]:
    """(t_pen, pi_pen, s_pen) for a (K, T) problem; feasibility-checked."""
    if not isinstance(constraint, ConstraintSpec):
        raise TypeError(f"expected a ConstraintSpec, got "
                        f"{type(constraint).__name__}")
    s_pen = step_penalty(constraint, K, T)
    return (transition_penalty(constraint, K),
            init_penalty(constraint, K), s_pen)


def constrain_inputs(constraint: ConstraintSpec, log_pi, log_A, emissions):
    """Apply a constraint as tropical-identity adds on the model inputs.

    Returns (log_pi', log_A', emissions') such that an *unconstrained* decode
    over the primed inputs is the constrained decode — this is the single
    masking code path: the oracle in the tests, the generic `DecodeSpec`
    fallback and the batched path all call it, and the fused/banded kernels
    reproduce its adds operand-for-operand so results stay bit-identical.

    `emissions` may be (T, K) or batched (B, T, K); the step penalty is
    shared across the batch (one schedule per constraint — per-sequence
    schedules are distinct constraints).
    """
    import jax.numpy as jnp

    K = log_A.shape[-1]
    T = emissions.shape[-2]
    t_pen, pi_pen, s_pen = compiled_penalties(constraint, K, T)
    if pi_pen is not None:
        log_pi = log_pi + jnp.asarray(pi_pen)
    if t_pen is not None:
        log_A = log_A + jnp.asarray(t_pen)
    if s_pen is not None:
        pen = jnp.asarray(s_pen)
        emissions = emissions + (pen if emissions.ndim == 2 else pen[None])
    return log_pi, log_A, emissions


def with_constraint(spec, constraint: Optional[ConstraintSpec]):
    """Return `spec` with its `constraint` field replaced (specs are frozen)."""
    return dataclasses.replace(spec, constraint=constraint)


def banded_state_bytes(K: int, T: int, width: int) -> int:
    """Live DP-state bytes of the banded fast path (window backpointers only).

    T windows of Kb = 2*width+1 local backpointers, the Kb-float frontier,
    and the T window starts — the band analogue of
    `planner.decoder_state_bytes("fused", ...)`'s K*T*4 + K*8.
    """
    Kb = min(2 * width + 1, K)
    return T * Kb * 4 + Kb * 8 + T * 4
