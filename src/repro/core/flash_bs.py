"""FLASH-BS Viterbi — dynamic beam search variant (paper Sec. V-C).

The paper maintains the running top-B candidates with a pair of double-buffered
min-heaps so that the full K-vector of scores is never materialised.  TPUs have no
efficient scalar heap, so we use the vectorised equivalent with identical
asymptotics: **streaming chunked top-B**.  Target states are scored in lane-aligned
chunks of C; each (B x C) candidate block is reduced per-target over the beam and
merged into the running top-B by `lax.top_k` over B + C entries.  Live state is
O(B + C), never O(K) — the defining property of *dynamic* (vs static) beam search.
The running-beam buffer and the merge buffer alternate roles every chunk, which is
the paper's double-buffering scheme expressed as an SSA loop carry.

The divide-and-conquer / pruning wavefront is shared with `flash.py`; only the
per-tile DP differs.  A tile's pinned exit state may occasionally be absent from
the child's final beam (the child explores a slightly different candidate set than
its parent under narrow beams); we then fall back to the best beam element, which
is the standard beam-search approximation and is what the paper's relative-error
metric (Fig. 9) quantifies.
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .hmm import NEG_INF
from .flash import plan_padding, chunked_vmap

_SENTINEL = 4.0 * NEG_INF  # below any reachable (even "unreachable-edge") score


def pad_state_space(log_pi, log_A, em, chunk: int):
    """Pad K up to a multiple of `chunk` with sentinel states.

    Fake states get sentinel emissions and sentinel in/out transitions so they
    can never displace real candidates from the beam.  `em` may be (T, K) or
    batched (..., T, K); the state axis is always last.  Returns
    (log_pi, log_A, em, K_pad).
    """
    K = log_A.shape[0]
    K_pad = int(math.ceil(K / chunk)) * chunk
    if K_pad != K:
        widths = [(0, 0)] * (em.ndim - 1) + [(0, K_pad - K)]
        em = jnp.pad(em, widths, constant_values=_SENTINEL / 2)
        log_A = jnp.pad(log_A, ((0, K_pad - K), (0, K_pad - K)),
                        constant_values=_SENTINEL / 2)
        log_pi = jnp.pad(log_pi, (0, K_pad - K), constant_values=_SENTINEL / 2)
    return log_pi, log_A, em, K_pad


# ---------------------------------------------------------------------------
# Streaming top-B primitives
# ---------------------------------------------------------------------------

def _stream_top_b(chunk_scores_fn, K_pad: int, chunk: int, B: int):
    """Top-B of a virtual length-K_pad score vector, materialising C at a time.

    chunk_scores_fn(c) -> (C,) scores of states [c*C, (c+1)*C).
    Returns (scores (B,), states (B,)) sorted descending.
    """
    nchunks = K_pad // chunk

    def body(c, carry):
        rs, rst = carry
        v = chunk_scores_fn(c)
        st = (c * chunk + jnp.arange(chunk)).astype(jnp.int32)
        all_s = jnp.concatenate([rs, v])
        all_st = jnp.concatenate([rst, st])
        top_s, idx = jax.lax.top_k(all_s, B)
        return top_s, all_st[idx]

    init = (jnp.full((B,), _SENTINEL, dtype=jnp.float32),
            jnp.zeros((B,), dtype=jnp.int32))
    return jax.lax.fori_loop(0, nchunks, body, init)


def _beam_transition(log_A, em_t, scores, states, chunk: int, B: int):
    """One dynamic-beam DP step.

    Returns (new_scores, new_states, from_idx) where from_idx[b] indexes the
    predecessor *beam slot* of new beam entry b.
    """
    K_pad = log_A.shape[1]
    nchunks = K_pad // chunk

    def body(c, carry):
        rs, rst, rfrom = carry
        colA = jax.lax.dynamic_slice(log_A, (0, c * chunk),
                                     (log_A.shape[0], chunk))   # (K, C)
        rows = colA[states]                                     # (B, C)
        em_c = jax.lax.dynamic_slice(em_t, (c * chunk,), (chunk,))
        cand = scores[:, None] + rows + em_c[None, :]           # (B, C)
        from_b = jnp.argmax(cand, axis=0).astype(jnp.int32)     # (C,)
        best = jnp.max(cand, axis=0)
        tgt = (c * chunk + jnp.arange(chunk)).astype(jnp.int32)
        all_s = jnp.concatenate([rs, best])
        all_st = jnp.concatenate([rst, tgt])
        all_f = jnp.concatenate([rfrom, from_b])
        top_s, idx = jax.lax.top_k(all_s, B)
        return top_s, all_st[idx], all_f[idx]

    init = (jnp.full((B,), _SENTINEL, dtype=jnp.float32),
            jnp.zeros((B,), dtype=jnp.int32),
            jnp.zeros((B,), dtype=jnp.int32))
    return jax.lax.fori_loop(0, nchunks, body, init)


def _pad_identity(is_pad, scores, states, ns, nst, nfrom):
    """Pad timesteps are tropical-identity: beam unchanged, self backpointers.

    (A full carry-freeze would be wrong: mid/div assignments that fire on a pad
    step must still see identity backpointers, mirroring `flash._dp_step`.)
    """
    B = scores.shape[0]
    eye = jnp.arange(B, dtype=jnp.int32)
    return (jnp.where(is_pad, scores, ns),
            jnp.where(is_pad, states, nst),
            jnp.where(is_pad, eye, nfrom))


# ---------------------------------------------------------------------------
# Initial pass (beam over full sequence, tracking P-1 division states)
# ---------------------------------------------------------------------------

def _bs_initial_pass(log_pi, log_A, em, pad, boundaries: np.ndarray,
                     B: int, chunk: int):
    Tp, K_pad = em.shape
    nb = len(boundaries)
    bnd = jnp.asarray(boundaries, dtype=jnp.int32)

    s0, st0 = _stream_top_b(
        lambda c: jax.lax.dynamic_slice(log_pi + em[0], (c * chunk,), (chunk,)),
        K_pad, chunk, B)
    div0 = jnp.zeros((B, nb), dtype=jnp.int32)

    def step(carry, inp):
        scores, states, div = carry
        em_t, is_pad, t = inp
        ns, nst, nfrom = _beam_transition(log_A, em_t, scores, states, chunk, B)
        ns, nst, nfrom = _pad_identity(is_pad, scores, states, ns, nst, nfrom)
        just = (t == bnd + 1)                       # (nb,)
        div_new = jnp.where(just[None, :], states[nfrom][:, None], div[nfrom, :])
        return (ns, nst, div_new), None

    ts = jnp.arange(1, Tp, dtype=jnp.int32)
    (scores, states, div), _ = jax.lax.scan(
        step, (s0, st0, div0), (em[1:], pad[1:], ts))
    b_best = jnp.argmax(scores)
    q_last = states[b_best]
    score = scores[b_best]
    q_bounds = div[b_best, :]
    return q_bounds, q_last, score


# ---------------------------------------------------------------------------
# Per-tile beam DP
# ---------------------------------------------------------------------------

def _bs_segment_decode(log_pi, log_A, em_seg, pad_seg, entry, exit_state,
                       is_first, B: int, chunk: int):
    s, K_pad = em_seg.shape
    tm = s // 2 - 1

    def init_chunk(c):
        em_c = jax.lax.dynamic_slice(em_seg[0], (c * chunk,), (chunk,))
        row = jax.lax.dynamic_slice(log_A[entry], (c * chunk,), (chunk,))
        pi_c = jax.lax.dynamic_slice(log_pi, (c * chunk,), (chunk,))
        return jnp.where(is_first, pi_c, row) + em_c

    s0, st0 = _stream_top_b(init_chunk, K_pad, chunk, B)
    mid0 = jnp.zeros((B,), dtype=jnp.int32)

    def step(carry, inp):
        scores, states, mid = carry
        em_t, is_pad, tl = inp
        ns, nst, nfrom = _beam_transition(log_A, em_t, scores, states, chunk, B)
        ns, nst, nfrom = _pad_identity(is_pad, scores, states, ns, nst, nfrom)
        mid_new = jnp.where(tl == tm + 1, states[nfrom], mid[nfrom])
        return (ns, nst, mid_new), None

    tls = jnp.arange(1, s, dtype=jnp.int32)
    (scores, states, mid), _ = jax.lax.scan(
        step, (s0, st0, mid0), (em_seg[1:], pad_seg[1:], tls))

    # exit state may have fallen off the beam: fall back to the best element
    hit = states == exit_state
    has = jnp.any(hit)
    idx = jnp.where(has, jnp.argmax(hit), jnp.argmax(scores))
    return mid[idx]


# ---------------------------------------------------------------------------
# Full decoder
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("P", "lanes", "B", "chunk"))
def _flash_bs_padded(log_pi, log_A, em, pad, P: int, lanes: int | None,
                     B: int, chunk: int):
    Tp, K_pad = em.shape
    seg0 = Tp // P

    boundaries = (np.arange(1, P) * seg0 - 1).astype(np.int64)
    q_bounds, q_last, score = _bs_initial_pass(
        log_pi, log_A, em, pad, boundaries, B, chunk)

    q_star = jnp.zeros((Tp,), dtype=jnp.int32)
    q_star = q_star.at[Tp - 1].set(q_last)
    if P > 1:
        q_star = q_star.at[jnp.asarray(boundaries)].set(q_bounds)

    s = seg0
    while s >= 2:
        n = Tp // s
        starts = np.arange(n, dtype=np.int64) * s
        ends = starts + s - 1
        mids = starts + s // 2 - 1
        em_tiles = em.reshape(n, s, K_pad)
        pad_tiles = pad.reshape(n, s)
        entries = q_star[jnp.asarray(np.maximum(starts - 1, 0))]
        exits = q_star[jnp.asarray(ends)]
        is_first = jnp.asarray(starts == 0)

        fn = partial(_bs_segment_decode, log_pi, log_A, B=B, chunk=chunk)
        mid_states = chunked_vmap(
            fn, (em_tiles, pad_tiles, entries, exits, is_first), lanes)
        q_star = q_star.at[jnp.asarray(mids)].set(mid_states)
        s //= 2
    return q_star, score


def flash_bs_viterbi(log_pi, log_A, em, beam_width: int = 128,
                     parallelism: int = 8, lanes: int | None = -1,
                     chunk: int = 128):
    """FLASH-BS Viterbi decode (dynamic beam search).

    Returns (path, score).  With beam_width >= K this is exact (ties aside);
    narrower beams trade accuracy for time/memory per paper Fig. 9.
    """
    T, K = em.shape
    P = int(parallelism)
    if lanes == -1:
        lanes = P
    B = int(min(beam_width, K))
    chunk = int(min(chunk, K))  # chunk == K degenerates to static beam search
    log_pi, log_A, em, _ = pad_state_space(log_pi, log_A, em, chunk)

    if T == 1:
        q = jnp.argmax(log_pi + em[0]).astype(jnp.int32)
        return q[None], (log_pi + em[0])[q]

    Tp, _ = plan_padding(T, P)
    em_p = jnp.pad(em, ((0, Tp - T), (0, 0)))
    pad = jnp.arange(Tp) >= T
    q_star, score = _flash_bs_padded(log_pi, log_A, em_p, pad, P, lanes, B, chunk)
    return q_star[:T], score


#: flashprove waivers (see analysis/findings.py for the grammar).
FLASHPROVE_WAIVERS = {
    "PV103:jaxpr:flash_bs:batch": (
        "the vmapped beam transition gathers/broadcasts a (batch, lanes, "
        "K, K) score block for one time step; per-step compute working set "
        "fused by XLA into the streaming top-B reduction, not retained "
        "state — the beam carry the planner models stays O(lanes x B)"),
}

__all__ = ["flash_bs_viterbi", "pad_state_space"]
