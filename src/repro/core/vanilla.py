"""Vanilla Viterbi in JAX: `lax.scan` forward pass + reverse-scan backtracking.

Baseline #1 of the paper (O(K^2 T) time, O(KT) space — the full psi table is
materialised).  This is also the semantic oracle for every optimised variant.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


@partial(jax.jit, static_argnames=())
def viterbi_vanilla(log_pi: jax.Array, log_A: jax.Array, em: jax.Array):
    """Exact Viterbi decode.

    Args:
      log_pi: (K,) initial log-probs.
      log_A:  (K, K) transition log-probs, [src, dst].
      em:     (T, K) emission log-likelihoods per timestep.

    Returns:
      (path, score): (T,) int32 optimal state sequence and its log-likelihood.
    """
    K = em.shape[1]

    def forward(delta, em_t):
        scores = delta[:, None] + log_A              # (K_src, K_dst)
        psi = jnp.argmax(scores, axis=0)             # (K_dst,)
        new = jnp.max(scores, axis=0) + em_t
        return new, psi

    delta0 = log_pi + em[0]
    delta_T, psis = jax.lax.scan(forward, delta0, em[1:])  # psis: (T-1, K)

    q_last = jnp.argmax(delta_T).astype(jnp.int32)
    score = delta_T[q_last]

    def backward(q, psi_t):
        q_prev = psi_t[q].astype(jnp.int32)
        return q_prev, q_prev

    _, path_prefix = jax.lax.scan(backward, q_last, psis, reverse=True)
    path = jnp.concatenate([path_prefix, q_last[None]])
    return path, score


@partial(jax.jit, static_argnames=())
def viterbi_vanilla_masked(log_pi, log_A, em, pad):
    """Exact Viterbi decode of a padded sequence.

    `pad` is a (T,) bool mask; masked steps are tropical identities (delta
    frozen, identity backpointers), so the returned score and the path prefix
    up to the true length are bit-identical to `viterbi_vanilla` on the
    unpadded sequence.  Path entries at padded steps repeat the final state.
    pad[0] must be False (length >= 1).
    """
    # the masked forward recursion has one spec, shared with the fused
    # kernel's fallback (lazy import: kernels sits above core in the layering)
    from repro.kernels.ref import viterbi_forward_masked_ref

    delta0 = log_pi + em[0]
    psis, delta_T = viterbi_forward_masked_ref(log_A, em[1:], delta0, pad[1:])

    q_last = jnp.argmax(delta_T).astype(jnp.int32)
    score = delta_T[q_last]

    def backward(q, psi_t):
        q_prev = psi_t[q].astype(jnp.int32)
        return q_prev, q_prev

    _, path_prefix = jax.lax.scan(backward, q_last, psis, reverse=True)
    path = jnp.concatenate([path_prefix, q_last[None]])
    return path, score


def viterbi_vanilla_batched(log_pi, log_A, em_batch):
    """vmap over a batch of emission sequences (B, T, K)."""
    return jax.vmap(lambda e: viterbi_vanilla(log_pi, log_A, e))(em_batch)


__all__ = ["viterbi_vanilla", "viterbi_vanilla_masked",
           "viterbi_vanilla_batched"]
