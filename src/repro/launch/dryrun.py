import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes, print memory/cost analysis, and emit roofline rows.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch tinyllama-1.1b \
        --shape train_4k [--multi-pod] [--json out.json]
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]

The XLA_FLAGS line above MUST run before any jax import: jax locks the device
count at first init.  512 host devices cover both the 256-chip single-pod mesh
and the 512-chip dual-pod mesh.
"""

import argparse
import json
import sys
import time
import traceback

import jax

from repro.configs import ARCH_IDS, get_arch
from repro.configs.base import SHAPES
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_cell, lower_cell
from repro.launch import roofline as rl


def run_cell(arch_id: str, shape: str, multi_pod: bool, verbose: bool = True,
             opts: frozenset = frozenset(), save_hlo: str | None = None):
    """Lower + compile one cell. Returns a result dict (or skip record)."""
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    chips = 512 if multi_pod else 256
    arch_mod = get_arch(arch_id)

    with mesh:
        cell = build_cell(arch_mod, shape, mesh, opts=opts)
        if cell is None:
            reason = arch_mod.SKIPS.get(shape, "n/a")
            if verbose:
                print(f"SKIP  {arch_id:24s} {shape:12s} {mesh_name}: {reason}")
            return {"arch": arch_id, "shape": shape, "mesh": mesh_name,
                    "status": "skip", "reason": reason}

        t0 = time.time()
        lowered = lower_cell(cell)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()

        mem = compiled.memory_analysis()
        hlo_text = compiled.as_text()
        if save_hlo:
            import gzip, os as _os
            _os.makedirs(save_hlo, exist_ok=True)
            tag = "-".join(sorted(opts)) or "base"
            fn = f"{arch_id}__{shape}__{mesh_name}__{tag}.txt.gz"
            with gzip.open(_os.path.join(save_hlo, fn), "wt") as f:
                f.write(hlo_text)
        kind, S, B = SHAPES[shape]
        mf = rl.model_flops_estimate(cell.model, kind, S, B)
        roof = rl.analyze(compiled, hlo_text, arch=arch_id,
                          shape=shape, mesh_name=mesh_name, chips=chips,
                          model_flops=mf)
        row = roof.row()
        row.update({
            "status": "ok", "kind": kind, "opts": sorted(opts),
            "lower_s": round(t1 - t0, 1), "compile_s": round(t2 - t1, 1),
            "temp_bytes_per_device": getattr(mem, "temp_size_in_bytes", None),
            "arg_bytes_per_device": getattr(mem, "argument_size_in_bytes", None),
            "out_bytes_per_device": getattr(mem, "output_size_in_bytes", None),
        })
        if verbose:
            print(f"OK    {arch_id:24s} {shape:12s} {mesh_name} "
                  f"kind={kind:7s} compile={row['compile_s']:6.1f}s "
                  f"temp/dev={row['temp_bytes_per_device']/2**30:6.2f}GiB "
                  f"arg/dev={row['arg_bytes_per_device']/2**30:6.2f}GiB "
                  f"dominant={row['dominant']:10s} "
                  f"roofline={row['roofline_fraction']:.3f}")
            print(f"      memory_analysis: {mem}")
        return row


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--json", default=None, help="append JSONL results here")
    ap.add_argument("--opt", action="append", default=[],
                    help="optimisation switches (banded_causal, grouped_moe, moe2d)")
    ap.add_argument("--save-hlo", default=None,
                    help="directory for gzipped compiled HLO (re-analysis)")
    args = ap.parse_args()

    assert len(jax.devices()) == 512, "dry-run needs 512 placeholder devices"

    cells = []
    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    results = []
    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    results.append(run_cell(arch, shape, mp,
                                            opts=frozenset(args.opt),
                                            save_hlo=args.save_hlo))
                except Exception as e:
                    failures += 1
                    traceback.print_exc()
                    results.append({"arch": arch, "shape": shape,
                                    "mesh": "2x16x16" if mp else "16x16",
                                    "status": "fail", "error": repr(e)})
                if args.json:
                    with open(args.json, "a") as f:
                        f.write(json.dumps(results[-1]) + "\n")
    ok = sum(1 for r in results if r["status"] == "ok")
    sk = sum(1 for r in results if r["status"] == "skip")
    print(f"\n=== dry-run: {ok} ok, {sk} skip, {failures} FAIL ===")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
