"""Useful-FLOPs model per (arch config, kind, shape).

6*N*D (params) alone misrepresents attention-heavy cells (an encoder at 32k
does most of its work in S^2 attention), so the useful-work yardstick is:

  train:   6*N_active*D   + 3 * attn_fwd     (fwd + 2x bwd, remat excluded)
  prefill: 2*N_active*D   + attn_fwd
  decode:  2*N_active*B   + attn_decode      (one token/stream vs the cache)

attn_fwd counts the two attention matmuls (QK^T and PV) at 2 FLOPs/MAC:
  full:    4 * B * S^2 * H * hd   (x1/2 when causal)
  window:  4 * B * S * min(S, W) * H * hd
MLA uses its true head dims (dn + dr for scores, dv for values); Griffin
counts only its attention layers; xLSTM counts the mLSTM parallel (quadratic,
causal) form at its 2x-width heads.  Recurrent (RG-LRU / sLSTM) elementwise
work is O(S*d) and negligible next to the projections already in 6ND.
"""

from __future__ import annotations


def _attn_tokens_pairs(S: int, causal: bool, window: int | None) -> float:
    """Sum over queries of attended positions."""
    if window is not None:
        w = min(S, window)
        return float(S) * w - (w * (w - 1) / 2 if causal else 0.0)
    if causal:
        return S * (S + 1) / 2.0
    return float(S) * S


def attention_fwd_flops(cfg, S: int, B: int) -> float:
    """Forward QK^T + PV FLOPs for the whole stack at sequence length S."""
    if cfg.family == "xlstm":
        # mLSTM parallel form: causal quadratic at 2x width, half the layers
        H, hd = cfg.num_heads, 2 * cfg.d_model // cfg.num_heads
        pairs = _attn_tokens_pairs(S, True, None)
        return 4.0 * B * pairs * H * hd * (cfg.num_layers // 2)
    if cfg.family == "griffin":
        n_attn = cfg.num_layers // 3
        pairs = _attn_tokens_pairs(S, True, cfg.window)
        return 4.0 * B * pairs * cfg.num_heads * cfg.hd * n_attn
    # transformer family
    if cfg.mla:
        dk = cfg.hd + cfg.mla.get("rope_head_dim", 64)
        dv = cfg.mla.get("v_head_dim", cfg.hd)
        per_pair = 2.0 * cfg.num_heads * (dk + dv)
    else:
        per_pair = 4.0 * cfg.num_heads * cfg.hd
    causal = cfg.causal and not cfg.encoder_only
    pairs = _attn_tokens_pairs(S, causal, cfg.window)
    return B * pairs * per_pair * cfg.num_layers


def attention_decode_flops(cfg, S_cache: int, B: int) -> float:
    """One-token attention against an S_cache-long cache."""
    if cfg.family == "xlstm":
        H, hd = cfg.num_heads, 2 * cfg.d_model // cfg.num_heads
        return 4.0 * B * H * hd * hd * (cfg.num_layers // 2)  # C matrix read
    if cfg.family == "griffin":
        n_attn = cfg.num_layers // 3
        w = min(S_cache, cfg.window or S_cache)
        return 4.0 * B * w * cfg.num_heads * cfg.hd * n_attn
    if cfg.mla:
        kvl = cfg.mla["kv_lora"] + cfg.mla.get("rope_head_dim", 64)
        # absorbed form: q_eff (H x kvl) scores + latent ctx
        return 4.0 * B * S_cache * cfg.num_heads * kvl * cfg.num_layers
    w = min(S_cache, cfg.window or S_cache)
    return 4.0 * B * w * cfg.num_heads * cfg.hd * cfg.num_layers


def useful_flops(model, kind: str, S: int, B: int) -> float:
    cfg = model.cfg
    n_active = model.active_param_count()
    if kind == "train":
        return 6.0 * n_active * S * B + 3.0 * attention_fwd_flops(cfg, S, B)
    if kind == "prefill":
        return 2.0 * n_active * S * B + attention_fwd_flops(cfg, S, B)
    return 2.0 * n_active * B + attention_decode_flops(cfg, S, B)


__all__ = ["useful_flops", "attention_fwd_flops", "attention_decode_flops"]
