"""Serving driver: batched forced alignment (the paper's workload, end-to-end).

    PYTHONPATH=src python -m repro.launch.serve --requests 32 --states 512 \
        --method flash_bs --beam 128

Spins up the encoder (smoke-sized hubert on CPU), a left-to-right HMM, the
FLASH(-BS) alignment head, and the batching scheduler; reports latency and
relative-error stats per request batch.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import left_to_right_hmm, viterbi_vanilla, relative_error
from repro.serving.alignment import AlignmentConfig, make_alignment_head
from repro.serving.scheduler import BatchScheduler


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--states", type=int, default=512)
    ap.add_argument("--classes", type=int, default=64)
    ap.add_argument("--method", default="flash_bs")
    ap.add_argument("--beam", type=int, default=128)
    ap.add_argument("--parallelism", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    key = jax.random.key(args.seed)
    k_hmm, key = jax.random.split(key)
    hmm = left_to_right_hmm(k_hmm, args.states, args.classes)

    acfg = AlignmentConfig(method=args.method, beam_width=args.beam,
                           parallelism=args.parallelism)
    head = make_alignment_head(hmm.log_pi, hmm.log_A, acfg)
    sched = BatchScheduler(head, max_batch=args.max_batch,
                           buckets=(128, 256, 512))

    rng = np.random.default_rng(args.seed)
    for _ in range(args.requests):
        T = int(rng.choice([96, 128, 200, 256, 384, 512]))
        em = rng.standard_normal((T, args.states)).astype(np.float32) * 2.0
        sched.submit(em)

    t0 = time.time()
    done = sched.drain()
    wall = time.time() - t0

    # accuracy vs exact decode on a sample
    errs = []
    for r in done[:8]:
        em = jnp.asarray(r.payload)
        _, opt = viterbi_vanilla(hmm.log_pi, hmm.log_A, em)
        errs.append(float(relative_error(opt, r.result[1])))
    print(f"served {len(done)} requests in {wall:.2f}s "
          f"({len(done)/wall:.1f} req/s), batches={sched.stats['batches']}, "
          f"mean pad frac={np.mean(sched.stats['padded_frac']):.2f}")
    print(f"relative error vs exact (sample of 8): "
          f"mean={np.mean(errs):.2e} max={np.max(errs):.2e}")
    return done


if __name__ == "__main__":
    main()
