"""Serving driver: batched forced alignment (the paper's workload, end-to-end).

    PYTHONPATH=src python -m repro.launch.serve --requests 32 --states 512 \
        --method flash_bs --beam 128

    # or let the planner pick (method, P, B) from a memory budget:
    PYTHONPATH=src python -m repro.launch.serve --requests 32 --budget-kb 64

Spins up the encoder (smoke-sized hubert on CPU), a left-to-right HMM, the
alignment head, and the batching scheduler; reports latency and
relative-error stats per request batch.  With ``--budget-kb`` the decode spec
comes from `core.planner.plan` — the budget covers the live DP state of a
full ``--max-batch`` bucket at the largest length bucket, which is the
paper's adaptivity story running end-to-end in the serving path.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (left_to_right_hmm, viterbi_vanilla, relative_error,
                        plan, ResourceBudget)
from repro.serving.alignment import AlignmentConfig, make_alignment_head
from repro.serving.scheduler import BatchScheduler

BUCKETS = (128, 256, 512)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--states", type=int, default=512)
    ap.add_argument("--classes", type=int, default=64)
    ap.add_argument("--method", default="flash_bs")
    ap.add_argument("--beam", type=int, default=128)
    ap.add_argument("--parallelism", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--budget-kb", type=float, default=None,
                    help="live decoder-state budget (KiB) for a full batch; "
                         "overrides --method/--beam/--parallelism via the "
                         "planner")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    key = jax.random.key(args.seed)
    k_hmm, key = jax.random.split(key)
    hmm = left_to_right_hmm(k_hmm, args.states, args.classes)

    if args.budget_kb is not None:
        decode_plan = plan(args.states, max(BUCKETS),
                           ResourceBudget(memory_bytes=int(args.budget_kb
                                                           * 1024)),
                           batch=args.max_batch)
        spec = decode_plan.spec
        print(f"planner: budget={args.budget_kb:.0f}KiB "
              f"x batch {args.max_batch} -> {spec}  [{decode_plan.why}]")
    else:
        spec = AlignmentConfig(method=args.method, beam_width=args.beam,
                               parallelism=args.parallelism).to_spec()
    head = make_alignment_head(hmm.log_pi, hmm.log_A, spec)
    sched = BatchScheduler(head, max_batch=args.max_batch, buckets=BUCKETS)

    rng = np.random.default_rng(args.seed)
    for _ in range(args.requests):
        T = int(rng.choice([96, 128, 200, 256, 384, 512]))
        em = rng.standard_normal((T, args.states)).astype(np.float32) * 2.0
        sched.submit(em)

    t0 = time.time()
    done = sched.drain()
    wall = time.time() - t0

    # accuracy vs exact decode on a sample
    errs = []
    for r in done[:8]:
        em = jnp.asarray(r.payload)
        _, opt = viterbi_vanilla(hmm.log_pi, hmm.log_A, em)
        errs.append(float(relative_error(opt, r.result[1])))
    print(f"served {len(done)} requests in {wall:.2f}s "
          f"({len(done)/wall:.1f} req/s), batches={sched.stats['batches']}, "
          f"mean pad frac={np.mean(sched.stats['padded_frac']):.2f}")
    print(f"relative error vs exact (sample of 8): "
          f"mean={np.mean(errs):.2e} max={np.max(errs):.2e}")
    return done


if __name__ == "__main__":
    main()
