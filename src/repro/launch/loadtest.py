"""End-to-end scale harness: load generation, fault drills, serving oracle.

Everything below the serve path is tested in isolation elsewhere (decoders,
kernels, shard maps); this module exercises the *system*: a deterministic,
seedable load generator drives ``BatchScheduler`` (offline requests),
``StreamMux`` (streaming sessions) and the planner's ``--budget-kb`` path
through one harness object, records throughput and latency percentiles to
``benchmarks/out/loadtest.json``, and checks every decoded path against a slow
reference oracle — so a scheduling, padding or rescale bug surfaces as a
bit-identity failure, not a perf blip.

Three pieces:

* **Load generation** (`make_workload`): ragged lengths drawn from a pool,
  bursty arrivals from a Markov-modulated Poisson process (all randomness from
  one injected `numpy` RNG; all time from a `VirtualClock`, so traces are
  reproducible byte-for-byte from the seed), and a streaming/offline request
  mix.  Streaming requests become open/feed/finish event sequences.

* **The differential serving oracle** (`oracle_check`): every delivered path
  is compared bit-for-bit against a looped single-sequence ``spec.run`` of the
  same spec on the unpadded payload (the true invariant batching/sharding must
  preserve), and against the pure-numpy ``core.reference`` decoder — score
  equality for exact specs, the optimal-score upper bound for beams.

* **Fault drills** (`drill_worker_death`, `drill_mesh_rescale`,
  `drill_budget_shrink`): scripted production events built on the injectable
  hooks in ``runtime/fault.py`` and ``checkpointing``: a worker dies
  mid-decode and the survivor restarts from the done-mask checkpoint with no
  lost or duplicated requests; the data mesh shrinks under load with results
  bit-identical across the rescale boundary; the memory budget shrinks
  mid-run and the planner's downgrade ladder engages while staying under
  budget.

CLI::

    PYTHONPATH=src python -m repro.launch.loadtest --requests 24 --states 32
    PYTHONPATH=src python -m repro.launch.loadtest --budget-kb 64 --drill all
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (ResourceBudget, erdos_renyi_hmm, plan,
                        spec_from_tunables)
from repro.core import reference as ref
from repro.core.hmm import HMM
from repro.core.spec import DecodeSpec, OnlineSpec
from repro.serving.alignment import make_alignment_head
from repro.serving.scheduler import BatchScheduler
from repro.serving.stream import StreamMux

__all__ = [
    "VirtualClock", "LoadConfig", "LoadEvent", "Workload", "make_workload",
    "resolve_spec", "oracle_check", "LoadHarness", "WorkerDied",
    "peak_concurrency", "run_inflight_compare",
    "drill_worker_death", "drill_mesh_rescale", "drill_budget_shrink",
    "run_drill", "DRILLS", "main",
]

DEFAULT_OUT = os.path.join("benchmarks", "out", "loadtest.json")


# ---------------------------------------------------------------------------
# Deterministic time
# ---------------------------------------------------------------------------

class VirtualClock:
    """Injectable simulation clock: arrivals live on a deterministic timeline.

    ``now`` has the same signature as ``time.monotonic``, so the clock plugs
    straight into ``runtime.fault.HeartbeatMonitor(clock=...)``.  Decode
    *service* time is real (measured around each device call and added to the
    timeline); everything else — arrivals, heartbeats, failure detection — is
    virtual, which is what makes the drills deterministic.
    """

    def __init__(self, start: float = 0.0):
        self._t = float(start)

    def now(self) -> float:
        return self._t

    def advance(self, dt: float) -> None:
        if dt < 0:
            raise ValueError(f"clock cannot run backwards (dt={dt})")
        self._t += dt

    def advance_to(self, t: float) -> None:
        self._t = max(self._t, float(t))


# ---------------------------------------------------------------------------
# Workload generation
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LoadConfig:
    """One load-test scenario; every field feeds the seeded generator.

    Arrivals follow a Markov-modulated Poisson process: a calm regime at
    ``1/mean_interarrival_s`` requests/s and a burst regime ``burst_factor``
    times faster, with per-arrival switch probabilities — bursty enough to
    pile up real queues without hand-scripting them.
    """
    seed: int = 0
    requests: int = 24
    states: int = 32                    # K
    edge_prob: float = 0.5
    stream_frac: float = 0.25           # fraction of requests that stream
    lengths: tuple[int, ...] = (12, 33, 64, 96, 128)
    buckets: tuple[int, ...] = (64, 128)
    max_batch: int = 8
    stream_block: int = 16              # StreamMux block bucket
    stream_chunk: int = 8               # frames per feed event
    frame_s: float = 1e-3               # virtual per-frame period for streams
    mean_interarrival_s: float = 4e-3
    burst_factor: float = 8.0
    p_enter_burst: float = 0.15
    p_exit_burst: float = 0.35
    method: str = "flash"               # offline spec when budget_kb is None
    budget_kb: float | None = None      # planner path: budget -> spec
    check_oracle: bool = True
    inflight: bool = False              # continuous batching for streams
    inflight_slots: int = 64            # slot-pool size when inflight

    def __post_init__(self):
        if not 0.0 <= self.stream_frac <= 1.0:
            raise ValueError(f"stream_frac must be in [0, 1], "
                             f"got {self.stream_frac}")
        if max(self.lengths) > max(self.buckets):
            raise ValueError(f"lengths {self.lengths} exceed the largest "
                             f"bucket {max(self.buckets)}")


@dataclasses.dataclass(frozen=True)
class LoadEvent:
    """One timeline entry; ``seq`` breaks ties deterministically."""
    t: float
    seq: int
    kind: str                       # offline | open | feed | finish
    rid: int
    frames: np.ndarray | None = None


@dataclasses.dataclass
class Workload:
    hmm: HMM
    events: list[LoadEvent]
    payloads: dict[int, np.ndarray]     # rid -> full (T, K) emissions
    kinds: dict[int, str]               # rid -> offline | stream


def make_workload(cfg: LoadConfig) -> Workload:
    """Generate the full arrival trace; byte-reproducible from cfg.seed."""
    rng = np.random.default_rng(cfg.seed)
    hmm = erdos_renyi_hmm(jax.random.key(cfg.seed), cfg.states,
                          edge_prob=cfg.edge_prob)
    events: list[LoadEvent] = []
    payloads: dict[int, np.ndarray] = {}
    kinds: dict[int, str] = {}
    t, seq, burst = 0.0, 0, False

    def emit(t, kind, rid, frames=None):
        nonlocal seq
        events.append(LoadEvent(t, seq, kind, rid, frames))
        seq += 1

    for rid in range(cfg.requests):
        burst = (rng.random() >= cfg.p_exit_burst if burst
                 else rng.random() < cfg.p_enter_burst)
        rate = (cfg.burst_factor if burst else 1.0) / cfg.mean_interarrival_s
        t += float(rng.exponential(1.0 / rate))
        T = int(rng.choice(cfg.lengths))
        em = (rng.standard_normal((T, cfg.states)) * 2.0).astype(np.float32)
        payloads[rid] = em
        if rng.random() < cfg.stream_frac:
            kinds[rid] = "stream"
            emit(t, "open", rid)
            ft = t
            for s in range(0, T, cfg.stream_chunk):
                chunk = em[s:s + cfg.stream_chunk]
                ft += cfg.frame_s * chunk.shape[0]
                emit(ft, "feed", rid, chunk)
            emit(ft + cfg.frame_s, "finish", rid)
        else:
            kinds[rid] = "offline"
            emit(t, "offline", rid, em)
    events.sort(key=lambda e: (e.t, e.seq))
    return Workload(hmm=hmm, events=events, payloads=payloads, kinds=kinds)


def resolve_spec(cfg: LoadConfig):
    """(offline spec, DecodePlan | None) — the ``--budget-kb`` alignment path."""
    if cfg.budget_kb is not None:
        p = plan(cfg.states, max(cfg.buckets),
                 ResourceBudget(memory_bytes=int(cfg.budget_kb * 1024)),
                 batch=cfg.max_batch)
        return p.spec, p
    spec, _ = spec_from_tunables(cfg.method, {})
    return spec, None


# ---------------------------------------------------------------------------
# Differential serving oracle
# ---------------------------------------------------------------------------

def _is_exact(spec: DecodeSpec, K: int) -> bool:
    if spec.method in ("online", "online_beam") and spec.max_lag is not None:
        return False
    if spec.method in ("flash_bs", "online_beam"):
        return spec.beam_width >= K
    if spec.method == "beam_static" or spec.method == "beam_static_mp":
        return spec.beam_width >= K
    return True


def oracle_check(spec: DecodeSpec, hmm: HMM,
                 payloads: dict[int, np.ndarray],
                 results: dict[int, tuple]) -> dict:
    """Check every delivered (path, score) against slow reference decodes.

    Per request:
      * bit-identity (path and score) versus a looped, unbatched, unpadded
        ``spec.run`` — the invariant the scheduler/mux/mesh must preserve;
      * the path's recomputed numpy score must equal the reported score;
      * versus ``reference.viterbi_numpy``: score equality for exact specs,
        the optimal-score upper bound for beams.
    """
    log_pi_np = np.asarray(hmm.log_pi)
    log_A_np = np.asarray(hmm.log_A)
    exact = _is_exact(spec, int(log_A_np.shape[0]))
    mismatches: list[dict] = []

    def bad(rid, what, got, want):
        mismatches.append({"rid": int(rid), "what": what,
                           "got": got, "want": want})

    for rid in sorted(results):
        path, score = results[rid]
        path, score = np.asarray(path), float(score)
        em = payloads[rid]
        if path.shape != (em.shape[0],):
            bad(rid, "path_shape", list(path.shape), [int(em.shape[0])])
            continue
        rp, rs = spec.run(hmm.log_pi, hmm.log_A, jnp.asarray(em))
        if not np.array_equal(path, np.asarray(rp)):
            n = int((path != np.asarray(rp)).sum())
            bad(rid, "path_vs_looped_spec", f"{n} frames differ", "0")
        if not np.isclose(score, float(rs), rtol=1e-6, atol=1e-6):
            bad(rid, "score_vs_looped_spec", score, float(rs))
        ps = ref.path_score_numpy(log_pi_np, log_A_np, em, path)
        if not np.isclose(ps, score, rtol=1e-5, atol=1e-4):
            bad(rid, "reported_score_vs_path", score, ps)
        _, ns = ref.viterbi_numpy(log_pi_np, log_A_np, em)
        if exact and not np.isclose(ps, ns, rtol=1e-5, atol=1e-4):
            bad(rid, "exact_path_not_optimal", ps, ns)
        if not exact and ps > ns + 1e-4:
            bad(rid, "beam_beats_optimum", ps, ns)
    return {"checked": len(results), "exact": exact,
            "mismatches": mismatches, "ok": not mismatches}


# ---------------------------------------------------------------------------
# The harness
# ---------------------------------------------------------------------------

def _pct(xs: list[float]) -> dict | None:
    if not xs:
        return None
    a = np.asarray(xs, np.float64)
    return {"p50": float(np.percentile(a, 50)),
            "p99": float(np.percentile(a, 99)),
            "mean": float(a.mean()), "max": float(a.max()), "n": len(xs)}


class LoadHarness:
    """Drives the serve path end-to-end under one generated trace.

    Offline requests go through ``BatchScheduler`` (batches fire whenever the
    queue reaches ``max_batch``, plus a final drain), streaming requests
    through ``StreamMux`` sessions fed chunk-by-chunk at their virtual arrival
    times.  ``chaos(batch_index)`` — if given — runs before every offline
    batch decode and may raise to simulate a production event (the drills use
    this); exceptions propagate to the caller, which owns recovery.
    """

    def __init__(self, cfg: LoadConfig, *, workload: Workload | None = None,
                 chaos=None, clock: VirtualClock | None = None):
        self.cfg = cfg
        self.work = workload if workload is not None else make_workload(cfg)
        self.clock = clock if clock is not None else VirtualClock()
        self.chaos = chaos
        self.spec, self.plan = resolve_spec(cfg)
        hmm = self.work.hmm
        self.head = make_alignment_head(hmm.log_pi, hmm.log_A, self.spec)
        self.sched = BatchScheduler(self.head, max_batch=cfg.max_batch,
                                    buckets=cfg.buckets)
        self.stream_spec = OnlineSpec(stream_chunk=cfg.stream_chunk)
        self.inflight = None
        if cfg.inflight:
            from repro.serving.inflight import InflightScheduler
            self.inflight = InflightScheduler(
                hmm.log_pi, hmm.log_A, max_slots=cfg.inflight_slots,
                block=cfg.stream_block)
        self.mux = StreamMux(hmm.log_pi, hmm.log_A, self.stream_spec,
                             blocks=(cfg.stream_block,),
                             inflight=self.inflight)
        self.results: dict[int, tuple] = {}         # offline rid -> result
        self.stream_results: dict[int, tuple] = {}  # stream rid -> result
        self.duplicates = 0
        self.batches = 0
        self.latency = {"offline": [], "stream_first_commit": [],
                        "stream_finish": [], "stream_feed": []}
        self.lag_frames: list[float] = []
        self._arrival: dict[int, float] = {}
        self._rid_of: dict[int, int] = {}           # scheduler rid -> load rid
        self._sid_of: dict[int, int] = {}           # load rid -> mux sid
        self._first_commit: set[int] = set()
        self.peak_stream_bytes = 0

    # -- plumbing -----------------------------------------------------------
    def _timed(self, fn, *args):
        t0 = time.perf_counter()
        out = fn(*args)
        self.clock.advance(time.perf_counter() - t0)
        return out

    def _deliver(self, results: dict, rid: int, result) -> None:
        if rid in results:
            self.duplicates += 1
        results[rid] = result

    def step_batch(self) -> int:
        """Run one offline batch (chaos hook first); returns requests done."""
        if self.chaos is not None:
            self.chaos(self.batches)
        done = self._timed(self.sched.step)
        self.batches += 1
        for r in done:
            rid = self._rid_of[r.rid]
            self._deliver(self.results, rid, r.result)
            self.latency["offline"].append(self.clock.now()
                                           - self._arrival[rid])
        return len(done)

    # -- event dispatch -----------------------------------------------------
    def _on_offline(self, ev: LoadEvent) -> None:
        self._arrival[ev.rid] = ev.t
        req = self.sched.submit(ev.frames)
        self._rid_of[req.rid] = ev.rid
        while len(self.sched.queue) >= self.cfg.max_batch:
            self.step_batch()

    def _on_open(self, ev: LoadEvent) -> None:
        self._arrival[ev.rid] = ev.t
        self._sid_of[ev.rid] = self.mux.open(block=self.cfg.stream_block)

    def _on_feed(self, ev: LoadEvent) -> None:
        t_before = self.clock.now()
        out = self._timed(self.mux.feed, self._sid_of[ev.rid], ev.frames)
        self.latency["stream_feed"].append(self.clock.now() - t_before)
        self.lag_frames.append(float(out["lag"]))
        if out["committed"].shape[0] and ev.rid not in self._first_commit:
            self._first_commit.add(ev.rid)
            self.latency["stream_first_commit"].append(
                self.clock.now() - self._arrival[ev.rid])
        self.peak_stream_bytes = max(self.peak_stream_bytes,
                                     self.mux.live_state_bytes())

    def _on_finish(self, ev: LoadEvent) -> None:
        path, score = self._timed(self.mux.finish, self._sid_of[ev.rid])
        self._deliver(self.stream_results, ev.rid, (path, score))
        self.latency["stream_finish"].append(self.clock.now()
                                             - self._arrival[ev.rid])

    def run(self) -> dict:
        """Play the whole trace, drain, and return the report dict."""
        dispatch = {"offline": self._on_offline, "open": self._on_open,
                    "feed": self._on_feed, "finish": self._on_finish}
        for ev in self.work.events:
            self.clock.advance_to(ev.t)
            dispatch[ev.kind](ev)
        while self.sched.queue:
            self.step_batch()
        return self.report()

    # -- reporting ----------------------------------------------------------
    def report(self) -> dict:
        cfg = self.cfg
        kinds = self.work.kinds
        n_off = sum(1 for k in kinds.values() if k == "offline")
        n_st = len(kinds) - n_off
        frames = sum(p.shape[0] for p in self.work.payloads.values())
        elapsed = max(self.clock.now(), 1e-9)
        delivered = len(self.results) + len(self.stream_results)
        rep = {
            "config": dataclasses.asdict(cfg),
            "spec": {"type": type(self.spec).__name__,
                     "method": self.spec.method,
                     "planned_why": self.plan.why if self.plan else None,
                     "planned_state_bytes":
                         self.plan.state_bytes if self.plan else None},
            "requests": {"total": cfg.requests, "offline": n_off,
                         "stream": n_st, "delivered": delivered,
                         "duplicates": self.duplicates},
            "throughput": {"requests_per_s": delivered / elapsed,
                           "frames_per_s": frames / elapsed,
                           "elapsed_s": elapsed},
            "latency_s": {k: _pct(v) for k, v in self.latency.items()},
            "scheduler": {"batches": self.sched.stats["batches"],
                          "mean_pad_frac":
                              float(np.mean(self.sched.stats["padded_frac"]))
                              if self.sched.stats["padded_frac"] else 0.0},
            "stream": {**{k: int(v) for k, v in self.mux.stats.items()},
                       "peak_live_state_bytes": int(self.peak_stream_bytes),
                       "commit_lag_frames": _pct(self.lag_frames)},
        }
        if self.inflight is not None:
            rep["inflight"] = self.inflight.slo_report()
        if cfg.check_oracle:
            hmm = self.work.hmm
            off_payloads = {r: self.work.payloads[r] for r in self.results}
            st_payloads = {r: self.work.payloads[r]
                           for r in self.stream_results}
            off = oracle_check(self.spec, hmm, off_payloads, self.results)
            st = oracle_check(self.stream_spec, hmm, st_payloads,
                              self.stream_results)
            rep["oracle"] = {"offline": off, "stream": st,
                             "ok": off["ok"] and st["ok"]}
        return rep


# ---------------------------------------------------------------------------
# Inflight vs. bucketed comparison
# ---------------------------------------------------------------------------

DEFAULT_INFLIGHT_OUT = os.path.join("benchmarks", "out", "inflight.json")


def peak_concurrency(work: Workload) -> int:
    """Max sessions simultaneously open in the trace (streams only)."""
    live = peak = 0
    for ev in work.events:
        if ev.kind == "open":
            live += 1
            peak = max(peak, live)
        elif ev.kind == "finish":
            live -= 1
    return peak


def run_inflight_compare(cfg: LoadConfig) -> dict:
    """Drive the *same* seeded MMPP trace through bucketed and inflight muxing.

    Both runs are all-streaming (`stream_frac=1.0`) and oracle-checked; the
    report carries p50/p99 feed/block latency, commit lag, and session
    first-commit/completion latency for each side, plus the head-to-head
    p99-completion verdict and the retrace count across the inflight run's
    session churn (must be zero — joins/leaves only change array contents).
    """
    from repro.serving.inflight import inflight_jit_fns

    base = dataclasses.replace(cfg, stream_frac=1.0, inflight=False)
    work = make_workload(base)
    concurrency = peak_concurrency(work)

    bucketed = LoadHarness(base, workload=work).run()

    infl_cfg = dataclasses.replace(base, inflight=True)
    harness = LoadHarness(infl_cfg, workload=work)
    # warm the slot pool once so the comparison (and the retrace count)
    # excludes first-trace compilation
    warm = harness.inflight.submit()
    harness.inflight.feed(
        warm, np.zeros((infl_cfg.stream_block + 1, cfg.states), np.float32))
    harness.inflight.pump()
    harness.inflight.finish(warm)
    cache0 = {k: f._cache_size() for k, f in inflight_jit_fns().items()}
    inflight = harness.run()
    cache1 = {k: f._cache_size() for k, f in inflight_jit_fns().items()}
    retraces = sum(cache1[k] - cache0[k] for k in cache0)

    def side(rep):
        return {"feed_latency_s": rep["latency_s"]["stream_feed"],
                "first_commit_s": rep["latency_s"]["stream_first_commit"],
                "completion_s": rep["latency_s"]["stream_finish"],
                "commit_lag_frames": rep["stream"]["commit_lag_frames"],
                "throughput": rep["throughput"],
                "oracle_ok": rep.get("oracle", {}).get("ok"),
                "stream_stats": rep["stream"]}

    b, i = side(bucketed), side(inflight)
    p99_b = (b["completion_s"] or {}).get("p99", float("nan"))
    p99_i = (i["completion_s"] or {}).get("p99", float("nan"))
    return {
        "config": dataclasses.asdict(infl_cfg),
        "peak_concurrent_sessions": concurrency,
        "bucketed": b,
        "inflight": {**i, "slo": inflight.get("inflight"),
                     "retraces_across_churn": int(retraces)},
        "p99_completion_s": {"bucketed": p99_b, "inflight": p99_i,
                             "speedup": (p99_b / p99_i if p99_i else
                                         float("nan"))},
        "p99_completion_win": bool(p99_i < p99_b),
        "oracle_ok": bool(b["oracle_ok"] and i["oracle_ok"]),
        "retraces": int(retraces),
    }


# ---------------------------------------------------------------------------
# Fault drills
# ---------------------------------------------------------------------------

class WorkerDied(RuntimeError):
    """Injected chaos: the worker holding the in-flight batch vanished."""


def drill_worker_death(cfg: LoadConfig, ckpt_dir: str | None = None, *,
                       kill_batch: int = 1, timeout_s: float = 5.0) -> dict:
    """Drill 1: worker death mid-decode -> heartbeat detect -> restart.

    Two simulated workers alternate offline batches, beating a
    ``HeartbeatMonitor`` driven by the virtual clock, and a done-mask
    checkpoint is written after every delivered batch.  At ``kill_batch`` the
    active worker dies *after* the scheduler popped its batch (those requests
    are in-flight on a dead host: gone).  The survivor notices the missed
    heartbeats, restores the latest checkpoint, resubmits exactly the
    requests the checkpoint does not cover, and drains.  Pass conditions:
    the dead worker is detected, every request is delivered exactly once,
    and every path is bit-identical to the oracle.
    """
    from repro.checkpointing.manager import CheckpointManager
    from repro.runtime.fault import HeartbeatMonitor

    cfg = dataclasses.replace(cfg, stream_frac=0.0)
    work = make_workload(cfg)
    spec, _ = resolve_spec(cfg)
    hmm = work.hmm
    head = make_alignment_head(hmm.log_pi, hmm.log_A, spec)
    if ckpt_dir is None:
        ckpt_dir = tempfile.mkdtemp(prefix="drill_worker_death_")
    ckpt = CheckpointManager(ckpt_dir, keep=2)
    clock = VirtualClock()
    mon = HeartbeatMonitor(num_workers=2, timeout_s=timeout_s,
                           clock=clock.now)
    N = cfg.requests
    done_mask = np.zeros((N,), np.bool_)
    delivered: dict[int, tuple] = {}
    duplicates = 0
    box = {"batch": 0, "die_at": kill_batch}

    def flaky_head(em, lengths=None):
        if box["die_at"] is not None and box["batch"] == box["die_at"]:
            box["die_at"] = None
            raise WorkerDied("node hosting the in-flight batch lost")
        return head(em, lengths)

    def fresh_sched(rids, fn):
        sched = BatchScheduler(fn, max_batch=cfg.max_batch,
                               buckets=cfg.buckets)
        rid_of = {}
        for rid in rids:
            req = sched.submit(work.payloads[rid])
            rid_of[req.rid] = rid
        return sched, rid_of

    sched, rid_of = fresh_sched(range(N), flaky_head)
    detected: list[int] = []
    restored_step = None
    resubmitted = 0
    while sched.queue:
        worker = box["batch"] % 2
        try:
            completed = sched.step()
        except WorkerDied:
            # the dead worker stops beating; the survivor keeps beating while
            # the monitor's timeout runs down on the virtual clock
            survivor = 1 - worker
            while not mon.dead_workers():
                clock.advance(1.0)
                mon.beat(survivor)
            detected = mon.dead_workers()
            # restart: trust only the checkpoint (the in-flight batch and the
            # dead worker's queue are gone); resubmit everything not done
            ckpt.wait()
            latest = ckpt.latest_step()
            restored_step = latest
            if latest is not None:
                state = ckpt.restore(latest,
                                     {"done": np.zeros((N,), np.bool_)})
                known_done = np.asarray(state["done"], np.bool_)
            else:
                known_done = np.zeros((N,), np.bool_)
            todo = [rid for rid in range(N) if not known_done[rid]]
            resubmitted = len(todo)
            sched, rid_of = fresh_sched(todo, head)
            continue
        box["batch"] += 1
        mon.beat(worker)
        mon.beat(1 - worker)
        clock.advance(0.25)
        for r in completed:
            rid = rid_of[r.rid]
            if rid in delivered:
                duplicates += 1
            delivered[rid] = r.result
            done_mask[rid] = True
        ckpt.save(box["batch"], {"done": done_mask.copy()})
    ckpt.wait()

    ora = oracle_check(spec, hmm, work.payloads, delivered)
    kill_worker = kill_batch % 2
    ok = (detected == [kill_worker] and len(delivered) == N
          and duplicates == 0 and ora["ok"])
    return {"drill": "worker_death", "ok": ok,
            "killed_batch": kill_batch, "killed_worker": kill_worker,
            "detected_dead": detected,
            "detected_at_s": clock.now(),
            "restored_from_step": restored_step,
            "resubmitted": resubmitted,
            "delivered": len(delivered), "expected": N,
            "duplicates": duplicates, "oracle": ora}


def drill_mesh_rescale(cfg: LoadConfig, *, from_devices: int = 4,
                       to_devices: int = 2) -> dict:
    """Drill 2: shrink the data mesh under load, bit-identical across it.

    The first half of the trace decodes sharded over a ``from_devices``-wide
    data mesh.  The rescale is then *planned* against an
    ``abstract_target_mesh`` (the login-host guard — no devices touched), the
    in-flight queue migrates to a fresh scheduler on the shrunken mesh, and
    the rest drains there.  A probe batch decoded on both meshes pins
    bit-identity across the boundary; the oracle covers every request from
    both phases.
    """
    from jax.sharding import PartitionSpec as P
    from repro.checkpointing.elastic import abstract_target_mesh, plan_rescale
    from repro.runtime.jaxcompat import make_mesh

    ndev = len(jax.devices())
    if ndev < from_devices:
        return {"drill": "mesh_rescale", "ok": False,
                "skipped": f"needs >= {from_devices} devices, have {ndev}"}
    cfg = dataclasses.replace(cfg, stream_frac=0.0)
    work = make_workload(cfg)
    spec, _ = resolve_spec(cfg)
    hmm = work.hmm

    mesh_from = make_mesh((from_devices,), ("data",),
                          devices=jax.devices()[:from_devices])
    mesh_to = make_mesh((to_devices,), ("data",),
                        devices=jax.devices()[:to_devices])
    head_from = make_alignment_head(hmm.log_pi, hmm.log_A, spec,
                                    mesh=mesh_from)
    head_to = make_alignment_head(hmm.log_pi, hmm.log_A, spec, mesh=mesh_to)

    N = cfg.requests
    delivered: dict[int, tuple] = {}
    duplicates = 0

    def submit_all(sched, rids):
        rid_of = {}
        for rid in rids:
            req = sched.submit(work.payloads[rid])
            rid_of[req.rid] = rid
        return rid_of

    def deliver(completed, rid_of):
        nonlocal duplicates
        for r in completed:
            rid = rid_of[r.rid]
            if rid in delivered:
                duplicates += 1
            delivered[rid] = r.result

    # phase 1: decode on the wide mesh until half the requests are out
    sched = BatchScheduler(head_from, max_batch=cfg.max_batch,
                           buckets=cfg.buckets)
    rid_of = submit_all(sched, range(N))
    while sched.queue and len(delivered) < N // 2:
        deliver(sched.step(), rid_of)
    phase1 = len(delivered)

    # plan the shrink against an abstract target before committing to it
    target = abstract_target_mesh((to_devices,), ("data",))
    bucket_shape = jax.ShapeDtypeStruct(
        (cfg.max_batch, max(cfg.buckets), cfg.states), jnp.float32)
    problems = plan_rescale({"emissions": bucket_shape},
                            {"emissions": P("data")}, target)

    # probe: the same padded batch must decode bit-identically on both meshes
    bucket = max(cfg.buckets)
    probe_rids = list(range(min(cfg.max_batch, N)))
    lens = np.asarray([work.payloads[r].shape[0] for r in probe_rids],
                      np.int32)
    probe = np.zeros((len(probe_rids), bucket, cfg.states), np.float32)
    for i, r in enumerate(probe_rids):
        probe[i, :lens[i]] = work.payloads[r]
    pf, sf = head_from(probe, lens)
    pt, st_ = head_to(probe, lens)
    probe_identical = (bool(np.array_equal(np.asarray(pf), np.asarray(pt)))
                       and bool(np.array_equal(np.asarray(sf),
                                               np.asarray(st_))))

    # phase 2: migrate the live queue onto the shrunken mesh and drain
    pending = list(sched.queue)
    sched.queue.clear()
    sched2 = BatchScheduler(head_to, max_batch=cfg.max_batch,
                            buckets=cfg.buckets)
    rid_of2 = {}
    for old in pending:
        req = sched2.submit(old.payload)
        rid_of2[req.rid] = rid_of[old.rid]
    while sched2.queue:
        deliver(sched2.step(), rid_of2)

    ora = oracle_check(spec, hmm, work.payloads, delivered)
    ok = (not problems and probe_identical and len(delivered) == N
          and duplicates == 0 and ora["ok"])
    return {"drill": "mesh_rescale", "ok": ok,
            "mesh": {"from": from_devices, "to": to_devices},
            "rescale_plan_problems": problems,
            "probe_bit_identical": probe_identical,
            "delivered_before_rescale": phase1,
            "delivered": len(delivered), "expected": N,
            "duplicates": duplicates, "oracle": ora}


def drill_budget_shrink(cfg: LoadConfig, *, big_kb: float = 64.0,
                        small_kb: float = 2.0) -> dict:
    """Drill 3: the memory budget shrinks mid-run; the ladder must engage.

    Phase 1 plans against ``big_kb`` (expected: an exact FLASH rung), serves
    half the trace, then the budget shrinks to ``small_kb`` and the planner
    re-plans — the downgrade ladder must pick a smaller-footprint spec whose
    reported state bytes stay under the new budget — and the rest of the
    trace serves on the downgraded spec.  Each phase's deliveries are checked
    against that phase's own spec oracle (phase 1 additionally against the
    optimal numpy score, being exact).
    """
    from repro.core import spec_state_bytes

    cfg = dataclasses.replace(cfg, stream_frac=0.0)
    work = make_workload(cfg)
    hmm = work.hmm
    K, Tmax = cfg.states, max(cfg.buckets)
    budgets = {"big": int(big_kb * 1024), "small": int(small_kb * 1024)}
    plan1 = plan(K, Tmax, ResourceBudget(memory_bytes=budgets["big"]),
                 batch=cfg.max_batch)
    plan2 = plan(K, Tmax, ResourceBudget(memory_bytes=budgets["small"]),
                 batch=cfg.max_batch)

    N = cfg.requests
    phases = {"big": list(range(N // 2)), "small": list(range(N // 2, N))}
    delivered_total = 0
    duplicates = 0
    oracles = {}
    for name, p in (("big", plan1), ("small", plan2)):
        head = make_alignment_head(hmm.log_pi, hmm.log_A, p.spec)
        sched = BatchScheduler(head, max_batch=cfg.max_batch,
                               buckets=cfg.buckets)
        rid_of = {}
        for rid in phases[name]:
            req = sched.submit(work.payloads[rid])
            rid_of[req.rid] = rid
        results: dict[int, tuple] = {}
        while sched.queue:
            for r in sched.step():
                rid = rid_of[r.rid]
                if rid in results:
                    duplicates += 1
                results[rid] = r.result
        delivered_total += len(results)
        payloads = {r: work.payloads[r] for r in results}
        oracles[name] = oracle_check(p.spec, hmm, payloads, results)

    footprint2 = spec_state_bytes(plan2.spec, K, Tmax) * cfg.max_batch
    downgraded = (plan2.spec != plan1.spec
                  and plan2.state_bytes < plan1.state_bytes)
    under_budget = footprint2 <= budgets["small"]
    ok = (downgraded and under_budget and delivered_total == N
          and duplicates == 0 and oracles["big"]["ok"]
          and oracles["small"]["ok"] and oracles["big"]["exact"])
    return {"drill": "budget_shrink", "ok": ok,
            "budgets_bytes": budgets,
            "plans": {name: {"spec": repr(p.spec), "why": p.why,
                             "state_bytes": p.state_bytes}
                      for name, p in (("big", plan1), ("small", plan2))},
            "downgraded": downgraded,
            "footprint_after_shrink_bytes": footprint2,
            "under_budget": under_budget,
            "delivered": delivered_total, "expected": N,
            "duplicates": duplicates, "oracle": oracles}


DRILLS = {"worker_death": drill_worker_death,
          "mesh_rescale": drill_mesh_rescale,
          "budget_shrink": drill_budget_shrink}


def run_drill(name: str, cfg: LoadConfig) -> dict:
    return DRILLS[name](cfg)


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--states", type=int, default=32)
    ap.add_argument("--stream-frac", type=float, default=0.25)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--method", default="flash")
    ap.add_argument("--budget-kb", type=float, default=None,
                    help="plan the offline spec from a memory budget "
                         "(the serve.py --budget-kb path, under load)")
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--no-oracle", action="store_true",
                    help="skip the reference-oracle pass (pure perf run)")
    ap.add_argument("--drill", choices=["none", "all", *DRILLS],
                    default="none")
    ap.add_argument("--inflight", action="store_true",
                    help="run the inflight-vs-bucketed streaming comparison "
                         "instead of the mixed harness; writes --inflight-out")
    ap.add_argument("--inflight-slots", type=int, default=64)
    ap.add_argument("--interarrival-us", type=float, default=None,
                    help="override mean interarrival (microseconds) — drive "
                         "this down to pile up concurrent sessions")
    ap.add_argument("--inflight-out", default=DEFAULT_INFLIGHT_OUT)
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args(argv)

    overrides = {}
    if args.interarrival_us is not None:
        overrides["mean_interarrival_s"] = args.interarrival_us * 1e-6
    cfg = LoadConfig(seed=args.seed, requests=args.requests,
                     states=args.states, stream_frac=args.stream_frac,
                     method=args.method, budget_kb=args.budget_kb,
                     max_batch=args.max_batch,
                     check_oracle=not args.no_oracle,
                     inflight_slots=args.inflight_slots, **overrides)

    if args.inflight:
        report = run_inflight_compare(cfg)
        p99 = report["p99_completion_s"]
        print(f"inflight compare: {cfg.requests} streaming sessions, peak "
              f"concurrency {report['peak_concurrent_sessions']}, "
              f"{cfg.inflight_slots} slots")
        print(f"  p99 completion: bucketed {p99['bucketed'] * 1e3:.1f}ms vs "
              f"inflight {p99['inflight'] * 1e3:.1f}ms "
              f"(speedup {p99['speedup']:.2f}x, "
              f"win={report['p99_completion_win']})")
        print(f"  oracle ok={report['oracle_ok']}, "
              f"retraces across churn={report['retraces']}")
        os.makedirs(os.path.dirname(args.inflight_out) or ".", exist_ok=True)
        with open(args.inflight_out, "w") as f:
            json.dump(report, f, indent=2, default=str)
        print(f"  wrote {args.inflight_out}")
        if not report["oracle_ok"] or report["retraces"]:
            raise SystemExit(1)
        return report

    harness = LoadHarness(cfg)
    report = harness.run()

    tp, lat = report["throughput"], report["latency_s"]
    off = lat["offline"] or {"p50": float("nan"), "p99": float("nan")}
    print(f"loadtest: {report['requests']['delivered']}/{cfg.requests} "
          f"requests ({report['requests']['stream']} streaming) in "
          f"{tp['elapsed_s']:.2f}s virtual — {tp['requests_per_s']:.1f} req/s"
          f", {tp['frames_per_s']:.0f} frames/s")
    print(f"  offline latency p50={off['p50'] * 1e3:.1f}ms "
          f"p99={off['p99'] * 1e3:.1f}ms; "
          f"batches={report['scheduler']['batches']}, "
          f"pad frac={report['scheduler']['mean_pad_frac']:.2f}")
    failed = False
    if "oracle" in report:
        print(f"  oracle: offline {report['oracle']['offline']['checked']} "
              f"checked, stream {report['oracle']['stream']['checked']} "
              f"checked, ok={report['oracle']['ok']}")
        failed |= not report["oracle"]["ok"]

    if args.drill != "none":
        names = list(DRILLS) if args.drill == "all" else [args.drill]
        report["drills"] = {}
        for name in names:
            d = run_drill(name, cfg)
            report["drills"][name] = d
            status = ("SKIP: " + d["skipped"] if d.get("skipped")
                      else ("ok" if d["ok"] else "FAIL"))
            print(f"  drill {name}: {status}")
            failed |= not (d["ok"] or d.get("skipped"))

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2, default=str)
    print(f"  wrote {args.out}")
    if failed:
        raise SystemExit(1)
    return report


if __name__ == "__main__":
    main()
