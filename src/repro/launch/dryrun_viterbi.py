import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Dry-run for the paper's operator itself on the production meshes:

  * 2-D-sharded FLASH Viterbi (subtask wavefront over `data`, tropical-TP
    row-sharded DP over `model`) at forced-alignment scale (K=4096 > the
    paper's K=3965, padded to lane width; T=512);
  * the batched serving decoder (sequences over `data`) at K=512, T=512,
    batch=256 — the alignment head behind hubert emissions.

    PYTHONPATH=src python -m repro.launch.dryrun_viterbi [--multi-pod]
"""

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.core.distributed import (make_flash_viterbi_2d,
                                    make_batched_flash_decoder)
from repro.launch.mesh import make_production_mesh
from repro.launch import roofline as rl


def run(multi_pod: bool, json_path: str | None = None, shard: str = "row"):
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    chips = 512 if multi_pod else 256
    rows = []

    with mesh:
        # --- 2-D sharded FLASH (tropical TP x subtask DP) -----------------
        K, T = 4096, 512
        dec = make_flash_viterbi_2d(mesh, T, K, shard=shard)
        args = (jax.ShapeDtypeStruct((K,), jnp.float32),
                jax.ShapeDtypeStruct((K, K), jnp.float32),
                jax.ShapeDtypeStruct((T, K), jnp.float32))
        t0 = time.time()
        compiled = dec.lower(*args).compile()
        dt = time.time() - t0
        useful = 2.0 * K * K * T  # one (max,+) matvec per step
        roof = rl.analyze(compiled, compiled.as_text(),
                          arch=f"flash-viterbi-2d-{shard}", shape=f"K{K}_T{T}",
                          mesh_name=mesh_name, chips=chips, model_flops=useful)
        row = roof.row()
        row.update({"status": "ok", "kind": "viterbi", "compile_s": round(dt, 1)})
        mem = compiled.memory_analysis()
        row["temp_bytes_per_device"] = mem.temp_size_in_bytes
        row["arg_bytes_per_device"] = mem.argument_size_in_bytes
        print(f"OK  flash-viterbi-2d K={K} T={T} {mesh_name} compile={dt:.1f}s "
              f"temp/dev={mem.temp_size_in_bytes/2**20:.1f}MiB "
              f"dominant={row['dominant']} coll={row['coll_detail']}")
        rows.append(row)

        # --- batched serving decoder (ragged lengths over the data axis) --
        K2, T2, B2 = 512, 512, 256
        bdec = make_batched_flash_decoder(mesh, method="flash")
        args = (jax.ShapeDtypeStruct((K2,), jnp.float32),
                jax.ShapeDtypeStruct((K2, K2), jnp.float32),
                jax.ShapeDtypeStruct((B2, T2, K2), jnp.float32),
                jax.ShapeDtypeStruct((B2,), jnp.int32))
        t0 = time.time()
        compiled = bdec.lower(*args).compile()
        dt = time.time() - t0
        useful = 2.0 * K2 * K2 * T2 * B2
        roof = rl.analyze(compiled, compiled.as_text(),
                          arch="flash-viterbi-batched", shape=f"B{B2}_K{K2}_T{T2}",
                          mesh_name=mesh_name, chips=chips, model_flops=useful)
        row = roof.row()
        row.update({"status": "ok", "kind": "viterbi", "compile_s": round(dt, 1)})
        mem = compiled.memory_analysis()
        row["temp_bytes_per_device"] = mem.temp_size_in_bytes
        row["arg_bytes_per_device"] = mem.argument_size_in_bytes
        print(f"OK  flash-viterbi-batched B={B2} K={K2} T={T2} {mesh_name} "
              f"compile={dt:.1f}s temp/dev={mem.temp_size_in_bytes/2**20:.1f}MiB "
              f"dominant={row['dominant']}")
        rows.append(row)

    if json_path:
        with open(json_path, "a") as f:
            for r in rows:
                f.write(json.dumps(r) + "\n")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--json", default=None)
    ap.add_argument("--shard", default="row")
    args = ap.parse_args()
    run(args.multi_pod, args.json, args.shard)
