"""Roofline analysis from compiled dry-run artifacts (no hardware required).

Per (arch x shape x mesh):
    compute term    = HLO_FLOPs / (chips x 197e12 FLOP/s bf16)
    memory term     = HLO_bytes / (chips x 819e9 B/s HBM)
    collective term = sum over collective ops of operand bytes
                      / (chips x 50e9 B/s x links)

HLO_FLOPs / HLO_bytes / collective bytes come from the trip-count-exact HLO
walker (hlo_cost.py) over the compiled module text — `compiled.cost_analysis()`
itself counts while bodies once, which undercounts scan-over-layers programs by
the layer count, so it is only kept as a cross-check field.  All walker totals
are per-device (the module is SPMD-partitioned).  The link-count heuristic: a
TPU v5e chip has ~4 usable ICI links at ~50 GB/s each; we charge collectives
against 2 links (one ring dimension in, one out) — documented, conservative,
and constant across cells so comparisons stay meaningful.

MODEL_FLOPS uses 6*N*D (train) / 2*N*D (inference) with N = active params.
"""

from __future__ import annotations

import dataclasses
import re


PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # B/s per chip
LINK_BW = 50e9               # B/s per ICI link
LINKS_USED = 2

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|[\w\[\],{}\s]+?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.M)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum output bytes per collective kind from compiled HLO text."""
    out: dict[str, int] = {}
    for m in _COLL_RE.finditer(hlo_text):
        shape_str, kind = m.group(1), m.group(2)
        if m.group(0).rstrip().endswith("-done("):
            continue  # start/done pairs: count the start only
        b = _shape_bytes(shape_str)
        out[kind] = out.get(kind, 0) + b
    return out


@dataclasses.dataclass
class Roofline:
    """All hlo_* fields are PER DEVICE (SPMD-partitioned module)."""
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    coll_bytes: dict
    model_flops: float            # GLOBAL useful flops (6ND / 2ND)
    bytes_per_device: float       # allocation footprint (memory_analysis)
    xla_cost_flops: float = 0.0   # raw cost_analysis() cross-check

    @property
    def compute_s(self) -> float:
        return self.hlo_flops / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.hlo_bytes / HBM_BW

    @property
    def collective_s(self) -> float:
        return sum(self.coll_bytes.values()) / (LINK_BW * LINKS_USED)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline step time = max of the three terms (perfect overlap)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_ratio(self) -> float:
        total = self.hlo_flops * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the compute roofline achieved at the bound:
        (useful FLOP time per chip) / (roofline step time)."""
        useful_s = self.model_flops / (self.chips * PEAK_FLOPS)
        return useful_s / self.step_time_s if self.step_time_s else 0.0

    def row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "hlo_flops": self.hlo_flops, "hlo_bytes": self.hlo_bytes,
            "coll_bytes": sum(self.coll_bytes.values()),
            "coll_detail": dict(self.coll_bytes),
            "model_flops": self.model_flops,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "useful_ratio": self.useful_ratio,
            "roofline_fraction": self.roofline_fraction,
            "bytes_per_device": self.bytes_per_device,
        }


def model_flops_estimate(model, kind: str, seq_len: int, batch: int) -> float:
    """Useful work: 6ND/2ND (active params) + attention matmuls — see
    launch/model_flops.py for the per-family attention terms."""
    from repro.launch.model_flops import useful_flops
    return useful_flops(model, kind, seq_len, batch)


def analyze(compiled, hlo_text: str, *, arch: str, shape: str, mesh_name: str,
            chips: int, model_flops: float) -> Roofline:
    from repro.launch import hlo_cost
    summary = hlo_cost.analyze_text(hlo_text)
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    mem = compiled.memory_analysis()
    per_dev = float(getattr(mem, "argument_size_in_bytes", 0) +
                    getattr(mem, "output_size_in_bytes", 0) +
                    getattr(mem, "temp_size_in_bytes", 0))
    return Roofline(arch=arch, shape=shape, mesh=mesh_name, chips=chips,
                    hlo_flops=summary.flops, hlo_bytes=summary.bytes,
                    coll_bytes=dict(summary.collective_bytes),
                    model_flops=model_flops, bytes_per_device=per_dev,
                    xla_cost_flops=float(cost.get("flops", 0.0)))


__all__ = ["Roofline", "collective_bytes", "analyze", "model_flops_estimate",
           "PEAK_FLOPS", "HBM_BW", "LINK_BW", "LINKS_USED"]
