"""repro.launch"""
