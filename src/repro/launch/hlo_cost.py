"""Trip-count-exact cost analysis of compiled HLO modules.

`compiled.cost_analysis()` counts every while-loop body ONCE — useless for
scan-over-layers programs (a 60-layer scan undercounts 60x).  This walker
parses the compiled module text, propagates execution multipliers through the
call graph using XLA's `known_trip_count` backend configs, and accumulates:

  * flops       — dot ops: 2 * out_elems * contracted_elems; elementwise ops:
                  1 flop/elem; reduces: input elems.  (Matches XLA's own
                  per-op model to roofline precision.)
  * bytes       — HBM traffic under a PERFECT-ELEMENTWISE-FUSION model: only
                  data-movement-bound ops are charged (dot, gather/scatter,
                  dynamic slice/update, reduce, sort, copy, concatenate,
                  collectives), with sliced reads charged at SLICE size (a
                  scan that dynamic-slices a (B,S,D) tensor per step reads
                  each element once in total, not T times).  Fusions are
                  never charged at their boundary; the walker descends and
                  applies the same rules inside, so pure elementwise fusions
                  cost nothing (TPU fuses them into neighbouring ops; the
                  CPU-backend module this walker reads leaves them unfused).
                  While bodies weighted by trip count.
  * collectives — per kind, payload bytes weighted by trip count.  Ring-
                  schedule accounting: all-reduce 2x size, reduce-scatter
                  counts its (large) input, all-gather/all-to-all/permute
                  their output.

All totals are PER DEVICE (the compiled module is the SPMD-partitioned
program); multiply flops by chip count for machine totals.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict, deque

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "f8e4m3fn": 1, "f8e5m2": 1,
}

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "exponential", "exponential-minus-one", "log", "log-plus-one",
    "tanh", "sqrt", "rsqrt", "power", "compare", "and", "or", "xor", "not",
    "select", "clamp", "sign", "floor", "ceil", "round-nearest-afz",
    "round-nearest-even", "sine", "cosine", "atan2", "remainder",
    "shift-left", "shift-right-arithmetic", "shift-right-logical",
    "is-finite", "logistic", "cbrt", "erf",
}

_BYTE_SKIP = {"parameter", "constant", "tuple", "get-tuple-element",
              "bitcast", "after-all", "partition-id", "replica-id", "iota",
              "while", "conditional", "call"}

# ops charged for HBM traffic (perfect-elementwise-fusion model; see docstring)
_BYTE_OPS = {"dot", "convolution", "gather", "scatter", "dynamic-slice",
             "dynamic-update-slice", "reduce", "reduce-window", "sort",
             "copy", "concatenate", "transpose", "reverse", "pad",
             "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
             "collective-permute", "all-reduce-start", "all-gather-start",
             "collective-permute-start"}

# a fusion is charged at its boundary only when its body does real data
# movement (the CPU backend wraps every lone elementwise op in a fusion; those
# are assumed fused into neighbouring dots on TPU and charged nothing)
_HARD_OPS = {"gather", "scatter", "dynamic-slice", "dynamic-update-slice",
             "reduce", "reduce-window", "sort", "concatenate", "transpose",
             "reverse", "pad", "dot", "convolution", "copy", "slice",
             "iota"} - {"iota"}

_COLLECTIVES = {"all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute"}

_SHAPE_ELEM_RE = re.compile(r"(\w+)\[([\d,]*)\]")

_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%(?P<name>[\w.\-]+)\s*=\s*"
    r"(?P<shape>\(.*?\)|[\w\[\]{},]+)\s+"
    r"(?P<opcode>[\w\-]+)\((?P<rest>.*)$")

_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?(?P<name>[\w.\-]+)\s*\(.*\)\s*->.*{\s*$")


def _shape_stats(shape_str: str) -> tuple[int, int]:
    """(total elements, total bytes) of a shape string (tuples summed)."""
    elems = bytes_ = 0
    for dt, dims in _SHAPE_ELEM_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        bytes_ += n * _DTYPE_BYTES[dt]
    return elems, bytes_


@dataclasses.dataclass
class Op:
    name: str
    shape: str
    opcode: str
    rest: str          # operand list + attributes
    operands: list

    @property
    def out_stats(self):
        return _shape_stats(self.shape)


def _parse_operands(rest: str) -> list[str]:
    """Operand %names up to the closing paren at depth 0."""
    out, depth = [], 0
    for tok in re.finditer(r"[(),]|%[\w.\-]+", rest):
        t = tok.group(0)
        if t == "(":
            depth += 1
        elif t == ")":
            if depth == 0:
                break
            depth -= 1
        elif t.startswith("%") and depth == 0:
            out.append(t[1:])
    return out


def parse_module(text: str) -> dict[str, list[Op]]:
    comps: dict[str, list[Op]] = {}
    cur: list[Op] | None = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_RE.match(line)
            if m and not line.lstrip().startswith("//"):
                comps[m.group("name")] = cur = []
            continue
        if line.startswith("}") or line.strip() == "}":
            cur = None
            continue
        m = _OP_RE.match(line)
        if m:
            cur.append(Op(name=m.group("name"), shape=m.group("shape"),
                          opcode=m.group("opcode"), rest=m.group("rest"),
                          operands=_parse_operands(m.group("rest"))))
    return comps


def _attr_comp(rest: str, key: str) -> list[str]:
    out = []
    for m in re.finditer(key + r"=%([\w.\-]+)", rest):
        out.append(m.group(1))
    m = re.search(key + r"={([^}]*)}", rest)
    if m:
        out += re.findall(r"%([\w.\-]+)", m.group(1))
    return out


def _trip_count(rest: str) -> int:
    m = re.search(r'known_trip_count[^\d]*(\d+)', rest)
    return int(m.group(1)) if m else 1


def _dot_flops(op: Op, symtab: dict[str, str]) -> float:
    out_elems, _ = op.out_stats
    lhs_shape = symtab.get(op.operands[0], "") if op.operands else ""
    m = re.search(r"lhs_contracting_dims={([\d,]*)}", op.rest)
    dims_m = _SHAPE_ELEM_RE.search(lhs_shape)
    if not m or not dims_m:
        return 2.0 * out_elems  # conservative fallback
    lhs_dims = [int(d) for d in dims_m.group(2).split(",") if d]
    contract = 1
    for i in (int(d) for d in m.group(1).split(",") if d):
        if i < len(lhs_dims):
            contract *= lhs_dims[i]
    return 2.0 * out_elems * contract


@dataclasses.dataclass
class CostSummary:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    unknown_trip_whiles: int = 0

    @property
    def total_collective_bytes(self) -> float:
        return float(sum(self.collective_bytes.values()))


def _op_bytes(op: Op, oc: str, out_bytes: int, symtab: dict,
              hard: dict) -> float:
    """HBM traffic of one op under the perfect-elementwise-fusion model.

    Sliced/gathered reads touch only the slice (charging the full operand
    would bill a scan T times for a tensor it reads once in total);
    dynamic-update-slice writes only the update region.
    """
    def in_bytes(idx=None):
        ops_ = op.operands if idx is None else [op.operands[i]
                                                for i in idx
                                                if i < len(op.operands)]
        return sum(_shape_stats(symtab.get(o, ""))[1] for o in ops_)

    if oc in ("dynamic-slice", "slice", "gather"):
        return 2.0 * out_bytes                      # read slice + write out
    if oc == "dynamic-update-slice":
        return 2.0 * in_bytes([1])                  # read + write the update
    if oc == "scatter":
        return 2.0 * in_bytes([2]) + in_bytes([1])  # updates r/w + indices
    if oc in ("dot", "convolution", "reduce", "reduce-window", "sort", "copy",
              "concatenate", "transpose", "reverse", "pad") or \
            oc in _COLLECTIVES or oc.replace("-start", "") in _COLLECTIVES:
        return out_bytes + in_bytes()
    return 0.0  # elementwise / fusion boundaries: free under perfect fusion


def analyze_text(text: str, entry: str | None = None) -> CostSummary:
    comps = parse_module(text)
    if not comps:
        return CostSummary()
    hard = {name: any(op.opcode in _HARD_OPS for op in ops)
            for name, ops in comps.items()}
    if entry is None:  # entry computation: the one never referenced as callee
        called = set()
        for ops in comps.values():
            for op in ops:
                for key in ("calls", "body", "condition", "to_apply",
                            "branch_computations"):
                    called.update(_attr_comp(op.rest, key))
        entries = [c for c in comps if c not in called]
        entry = entries[-1] if entries else next(iter(comps))

    summary = CostSummary()
    # (comp, multiplier) — byte rules apply inside fusions too (slice-aware)
    queue: deque[tuple[str, float, bool]] = deque([(entry, 1.0, False)])
    seen_budget = 0
    while queue:
        seen_budget += 1
        if seen_budget > 200_000:
            break
        comp, mult, fused = queue.popleft()
        ops = comps.get(comp, [])
        symtab = {op.name: op.shape for op in ops}
        for op in ops:
            oc = op.opcode
            out_elems, out_bytes = op.out_stats
            # --- flops -------------------------------------------------
            if oc == "dot":
                summary.flops += mult * _dot_flops(op, symtab)
            elif oc in _ELEMENTWISE:
                summary.flops += mult * out_elems
            elif oc in ("reduce", "reduce-window"):
                in_elems = sum(_shape_stats(symtab.get(o, ""))[0]
                               for o in op.operands[:1])
                summary.flops += mult * in_elems
            elif oc == "convolution":
                summary.flops += mult * 2.0 * out_elems  # none in this code
            # --- control flow -------------------------------------------
            if oc == "while":
                trips = _trip_count(op.rest)
                if "known_trip_count" not in op.rest:
                    summary.unknown_trip_whiles += 1
                for b in _attr_comp(op.rest, "body"):
                    queue.append((b, mult * trips, fused))
                for c in _attr_comp(op.rest, "condition"):
                    queue.append((c, mult * (trips + 1), fused))
            elif oc == "fusion":
                for c in _attr_comp(op.rest, "calls"):
                    queue.append((c, mult, fused))
            elif oc in ("call", "async-start", "custom-call"):
                for c in _attr_comp(op.rest, "to_apply") + \
                        _attr_comp(op.rest, "called_computations"):
                    queue.append((c, mult, fused))
            elif oc == "conditional":
                for c in _attr_comp(op.rest, "branch_computations"):
                    queue.append((c, mult, fused))
            # --- collectives ---------------------------------------------
            base = oc.replace("-start", "")
            if base in _COLLECTIVES and not oc.endswith("-done"):
                if base == "reduce-scatter":
                    payload = sum(_shape_stats(symtab.get(o, ""))[1]
                                  for o in op.operands)
                elif base == "all-reduce":
                    payload = 2.0 * out_bytes
                else:
                    payload = out_bytes
                summary.collective_bytes[base] += mult * payload
            # --- bytes ----------------------------------------------------
            summary.bytes += mult * _op_bytes(op, oc, out_bytes, symtab, hard)
    return summary


__all__ = ["CostSummary", "analyze_text", "parse_module"]
