"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
        --smoke --steps 100 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

On this CPU container `--smoke` selects the reduced config (the full configs
train only on real pods); the loop is the production one regardless: sharded
train_step under the active mesh, async checkpointing, resumable step-indexed
data, supervised restarts (chaos-injectable), straggler logging.
"""

from __future__ import annotations

import argparse
import time

import jax

from repro.configs import get_arch
from repro.data.pipeline import SyntheticTokenPipeline, TokenPipelineConfig
from repro.models import build_model
from repro.checkpointing.manager import CheckpointManager
from repro.runtime.fault import StragglerDetector
from repro.optim.adamw import AdamWConfig
from repro.train import TrainConfig, init_train_state, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--horizon", type=int, default=None,
                    help="LR-schedule horizon (default: --steps); set it to"
                         " the FULL run length when pre-empting early so the"
                         " schedule is restart-invariant")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--compress-accum", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    arch = get_arch(args.arch)
    cfg = arch.SMOKE if args.smoke else arch.CONFIG
    model = build_model(cfg)
    print(f"arch={cfg.name} params={model.param_count():,}")

    horizon = args.horizon or args.steps
    tcfg = TrainConfig(
        opt=AdamWConfig(lr=args.lr, total_steps=horizon,
                        warmup_steps=max(horizon // 20, 5)),
        accum_steps=args.accum, compress_accum=args.compress_accum)
    step_fn = jax.jit(make_train_step(model, tcfg), donate_argnums=0)

    pipe = SyntheticTokenPipeline(TokenPipelineConfig(
        vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch,
        seed=args.seed, kind="vlm" if cfg.num_image_tokens else "tokens",
        num_image_tokens=min(cfg.num_image_tokens, args.seq // 2),
        d_model=cfg.d_model))

    ckpt = CheckpointManager(args.ckpt_dir, keep=3)
    state = init_train_state(model, jax.random.key(args.seed))
    start = 0
    if args.resume and ckpt.latest_step() is not None:
        start = ckpt.latest_step()
        state = ckpt.restore(start, state)
        print(f"resumed from step {start}")

    strag = StragglerDetector(num_workers=1)
    losses = []
    t_start = time.time()
    cur = state
    for step in range(start, args.steps):
        t0 = time.time()
        batch = jax.tree_util.tree_map(jax.numpy.asarray, pipe.batch(step))
        cur, metrics = step_fn(cur, batch)
        dt = time.time() - t0
        strag.record(0, dt)
        losses.append(float(metrics["loss"]))
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss={losses[-1]:.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"lr={float(metrics['lr']):.2e} {dt*1e3:.0f}ms")
        if (step + 1) % args.ckpt_every == 0:
            ckpt.save(step + 1, cur)
    ckpt.save(args.steps, cur, blocking=True)
    wall = time.time() - t_start
    print(f"done: {args.steps - start} steps in {wall:.1f}s; "
          f"loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    return losses


if __name__ == "__main__":
    main()
