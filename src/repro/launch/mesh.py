"""Production mesh construction.

Single pod: 16 x 16 = 256 chips, axes (data, model).
Multi-pod:  2 x 16 x 16 = 512 chips, axes (pod, data, model) — the pod axis
carries only data parallelism / ZeRO reduce-scatter (DCI-friendly); no TP
collective crosses pods.

Meshes are built through `runtime.jaxcompat.make_mesh`, which passes
``AxisType.Auto`` only on jax versions that have it — this module must import
and run on the pinned 0.4.x toolchain as well as current jax.

Functions, not module-level constants: importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before any jax import).
"""

from __future__ import annotations

from repro.runtime.jaxcompat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_test_mesh(*, multi_pod: bool = False):
    """Small-device-count analogue for CI (8 fake devices)."""
    shape = (2, 2, 2) if multi_pod else (4, 2)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def data_axis_size(mesh) -> int:
    size = mesh.shape["data"]
    if "pod" in mesh.shape:
        size *= mesh.shape["pod"]
    return size


__all__ = ["make_production_mesh", "make_test_mesh", "data_axis_size"]
