"""Render the §Dry-run and §Roofline tables of EXPERIMENTS.md from the
dry-run JSONL (recomputing the useful-FLOPs yardstick from configs, so rows
produced before a yardstick change stay comparable).

    PYTHONPATH=src python -m repro.launch.render_experiments \
        dryrun_results.jsonl > /tmp/tables.md
"""

from __future__ import annotations

import json
import sys

from repro.configs import get_arch
from repro.configs.base import SHAPES
from repro.launch.model_flops import useful_flops
from repro.launch.roofline import PEAK_FLOPS
from repro.models import build_model

_MODEL_CACHE = {}


def fixup(row: dict) -> dict:
    """Recompute model_flops / useful / fraction from the current yardstick."""
    if row.get("status") != "ok":
        return row
    arch = row["arch"].replace("-", "_").replace(".", "_")
    if arch not in _MODEL_CACHE:
        _MODEL_CACHE[arch] = build_model(get_arch(arch).CONFIG)
    model = _MODEL_CACHE[arch]
    kind, S, B = SHAPES[row["shape"]]
    mf = useful_flops(model, kind, S, B)
    chips = row["chips"]
    row = dict(row)
    row["model_flops"] = mf
    row["useful_ratio"] = mf / (row["hlo_flops"] * chips) if row["hlo_flops"] else 0
    step = max(row["compute_s"], row["memory_s"], row["collective_s"])
    row["step_time_s"] = step
    row["roofline_fraction"] = (mf / (chips * PEAK_FLOPS)) / step if step else 0
    terms = {"compute": row["compute_s"], "memory": row["memory_s"],
             "collective": row["collective_s"]}
    row["dominant"] = max(terms, key=terms.get)
    return row


def gib(x):
    return f"{x / 2**30:.2f}"


def render(path: str, mesh_filter: str | None = None):
    rows = [fixup(json.loads(l)) for l in open(path)]
    # keep the last entry per (arch, shape, mesh)
    dedup = {}
    for r in rows:
        dedup[(r["arch"], r["shape"], r["mesh"])] = r
    rows = list(dedup.values())

    out = []
    out.append("| arch | shape | mesh | kind | compile s | args GiB/dev | "
               "temp GiB/dev | collectives |")
    out.append("|---|---|---|---|---|---|---|---|")
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        if r["status"] == "skip":
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                       f"SKIP | — | — | — | {r['reason']} |")
            continue
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | FAIL "
                       f"| — | — | — | {r.get('error','')[:60]} |")
            continue
        coll = ", ".join(f"{k.split('-')[-1]}:{v/2**30:.2f}G"
                         for k, v in sorted(r["coll_detail"].items()))
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['kind']} "
            f"| {r['compile_s']} | {gib(r['arg_bytes_per_device'])} "
            f"| {gib(r['temp_bytes_per_device'])} | {coll or '—'} |")
    dry = "\n".join(out)

    out = []
    out.append("| arch | shape | compute s | memory s | coll s | dominant | "
               "useful | roofline frac | note |")
    out.append("|---|---|---|---|---|---|---|---|---|")
    singles = [r for r in rows if r["status"] == "ok" and r["mesh"] == "16x16"]
    for r in sorted(singles, key=lambda r: (r["arch"], r["shape"])):
        note = _note(r)
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3f} "
            f"| {r['memory_s']:.3f} | {r['collective_s']:.3f} "
            f"| **{r['dominant']}** | {r['useful_ratio']:.2f} "
            f"| {r['roofline_fraction']:.3f} | {note} |")
    roof = "\n".join(out)
    return dry, roof, rows


def _note(r) -> str:
    if r["dominant"] == "compute" and r["useful_ratio"] < 0.6:
        return "compute waste: causal-masked full blocks / remat — skip masked KV blocks"
    if r["dominant"] == "memory" and r["kind"] == "decode":
        return "weight+cache streaming bound — batch more streams or quantize"
    if r["dominant"] == "memory":
        return "activation traffic — fuse/enlarge blocks, check remat policy"
    if r["dominant"] == "collective":
        return "MoE dispatch + TP all-reduce — group-local routing / overlap"
    return ""


if __name__ == "__main__":
    dry, roof, rows = render(sys.argv[1] if len(sys.argv) > 1
                             else "dryrun_results.jsonl")
    print("## Dry-run\n")
    print(dry)
    print("\n## Roofline (single pod 16x16)\n")
    print(roof)
