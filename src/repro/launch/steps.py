"""Build the jitted step for one (arch x shape x mesh) dry-run cell.

Shared by dryrun.py (lower+compile), roofline.py (cost/memory analysis) and
train.py (the real thing).  Given an arch module and a shape name this
constructs:
  * the step function (train_step / prefill / serve_step),
  * abstract example args (ShapeDtypeStruct — nothing is allocated),
  * in/out shardings.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import build_model
from repro.sharding.rules import MULTI_POD_RULES, SINGLE_POD_RULES
from repro.train import (TrainConfig, abstract_train_state, make_train_step,
                         train_state_specs)
from repro.launch.mesh import data_axis_size


@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    kind: str
    fn: Callable            # jit-able step
    args: tuple             # abstract args (ShapeDtypeStructs)
    in_shardings: tuple
    out_shardings: Any
    donate: tuple = ()
    model: Any = None


def _sharding_tree(mesh: Mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def build_cell(arch_mod, shape: str, mesh: Mesh,
               tcfg: TrainConfig | None = None,
               config_override=None, opts: frozenset = frozenset()) -> Cell | None:
    """Returns the Cell for (arch, shape) on this mesh, or None if skipped.

    opts — the §Perf optimisation switches (baseline has none):
      banded_causal — 4-band causal KV skipping (compute term)
      grouped_moe   — group-local MoE routing (collective term)
      moe2d         — 2-D expert-weight sharding (memory term, decode)
    """
    multi_pod = "pod" in mesh.shape
    spec = arch_mod.input_specs(shape, multi_pod=multi_pod)
    if spec is None:
        return None
    cfg = config_override or arch_mod.CONFIG
    if "banded_causal" in opts:
        cfg = dataclasses.replace(cfg, causal_schedule="banded")
    if "grouped_moe" in opts and cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, num_groups=32))
    model = build_model(cfg)
    rules = MULTI_POD_RULES if multi_pod else SINGLE_POD_RULES
    if "moe2d" in opts:
        rules = dataclasses.replace(
            rules, rules={**rules.rules, "expert_ff": "data"})
    arch_name = cfg.name

    if spec.kind == "train":
        tcfg = tcfg or TrainConfig()
        step = make_train_step(model, tcfg)
        state = abstract_train_state(model)
        state_specs = train_state_specs(model, rules, data_axis_size(mesh))
        state_sh = _sharding_tree(mesh, state_specs)
        batch_sh = _sharding_tree(mesh, spec.shardings["batch"])
        out_sh = (state_sh, {"loss": NamedSharding(mesh, P()),
                             "grad_norm": NamedSharding(mesh, P()),
                             "lr": NamedSharding(mesh, P())})
        return Cell(arch=arch_name, shape=shape, kind="train",
                    fn=step, args=(state, spec.args["batch"]),
                    in_shardings=(state_sh, batch_sh), out_shardings=out_sh,
                    donate=(0,), model=model)

    params = model.abstract_params()
    pspecs = model.param_specs(rules)
    params_sh = _sharding_tree(mesh, pspecs)

    batch_axes = rules.axis("batch")
    logits_sh = NamedSharding(mesh, P(batch_axes, None, None))

    if spec.kind == "prefill":
        def prefill(params, batch):
            logits, cache = model.prefill(params, batch)
            return logits, cache
        batch_sh = _sharding_tree(mesh, spec.shardings["batch"])
        if getattr(cfg, "encoder_only", False):
            out_sh = (logits_sh, None)  # encoder: emissions only, no cache
        else:
            # prefill cache shardings == decode cache shardings (ring-aligned)
            cache_sh = _sharding_tree(mesh, model.cache_specs(rules))
            out_sh = (logits_sh, cache_sh)
        return Cell(arch=arch_name, shape=shape, kind="prefill",
                    fn=prefill, args=(params, spec.args["batch"]),
                    in_shardings=(params_sh, batch_sh),
                    out_shardings=out_sh, model=model)

    # decode: serve_step(params, tokens, cache) -> (logits, cache)
    def serve_step(params, tokens, cache):
        return model.decode_step(params, tokens, cache)

    # long_500k (batch=1) replicates batch: rebuild rules the same way
    if spec.batch == 1:
        rules = dataclasses.replace(rules, rules={**rules.rules, "batch": None})
        logits_sh = NamedSharding(mesh, P(None, None, None))
    cache_sh = _sharding_tree(mesh, spec.shardings["cache"])
    tok_sh = NamedSharding(mesh, spec.shardings["tokens"])
    return Cell(arch=arch_name, shape=shape, kind="decode",
                fn=serve_step,
                args=(params, spec.args["tokens"], spec.args["cache"]),
                in_shardings=(params_sh, tok_sh, cache_sh),
                out_shardings=(logits_sh, cache_sh), donate=(2,), model=model)


def lower_cell(cell: Cell):
    jitted = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                     out_shardings=cell.out_shardings,
                     donate_argnums=cell.donate)
    return jitted.lower(*cell.args)


__all__ = ["Cell", "build_cell", "lower_cell"]
