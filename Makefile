# Tier-1 verification and common entry points.  `make test` is the command
# README and CI agree on; it matches ROADMAP.md's tier-1 invocation.

PY ?= python

.PHONY: test test-fast bench example-quickstart example-streaming

test:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} $(PY) -m pytest -x -q

test-fast:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} $(PY) -m pytest -x -q \
	    tests/test_core_viterbi.py tests/test_kernels.py tests/test_online.py

bench:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} $(PY) -m benchmarks.run

example-quickstart:
	$(PY) examples/quickstart.py

example-streaming:
	$(PY) examples/streaming_decode.py
