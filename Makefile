# Tier-1 verification and common entry points.  `make test` is the command
# README and CI agree on; it matches ROADMAP.md's tier-1 invocation.

PY ?= python

.PHONY: test test-fast bench bench-smoke example-quickstart example-streaming \
	example-batch

test:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} $(PY) -m pytest -x -q

test-fast:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} $(PY) -m pytest -x -q \
	    tests/test_core_viterbi.py tests/test_kernels.py tests/test_batch.py \
	    tests/test_online.py

bench:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} $(PY) -m benchmarks.run

bench-smoke:  # ~30 s benchmark smoke used by CI (kernel model + batched decode)
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} $(PY) -m benchmarks.run --quick

example-quickstart:
	$(PY) examples/quickstart.py

example-streaming:
	$(PY) examples/streaming_decode.py

example-batch:
	$(PY) examples/batch_decode.py
