# Tier-1 verification and common entry points.  `make test` is the command
# README and CI agree on; it matches ROADMAP.md's tier-1 invocation.

PY ?= python

.PHONY: test test-fast test-dist test-drills bench bench-smoke \
	example-quickstart example-streaming example-batch example-adaptive \
	serve-smoke loadtest-smoke inflight-smoke constrained-smoke \
	lint lint-fast analysis-deep

lint:  # the full gate: flashlint (AST + contracts + retrace) + fast flashprove, then ruff/mypy if installed
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} $(PY) -m repro.analysis
	@if command -v ruff >/dev/null 2>&1; then ruff check .; \
	else echo "ruff not installed (pip install -e '.[lint]'); skipping"; fi
	@if command -v mypy >/dev/null 2>&1; then mypy; \
	else echo "mypy not installed (pip install -e '.[lint]'); skipping"; fi

lint-fast:  # sub-second AST pass only (what pre-commit runs)
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} $(PY) -m repro.analysis --lint-only

analysis-deep:  # full flashprove: jaxpr + Pallas VMEM ladder + collective walk, JSON report
	@mkdir -p benchmarks/out
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} $(PY) -m repro.analysis \
	    --prove-only --deep --report benchmarks/out/flashprove.json

test:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} $(PY) -m pytest -x -q

test-fast:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} $(PY) -m pytest -x -q \
	    tests/test_core_viterbi.py tests/test_kernels.py tests/test_batch.py \
	    tests/test_online.py

test-dist:  # distributed suite: 8 virtual host devices (subprocess-forced) + compat shim
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} \
	    XLA_FLAGS="--xla_force_host_platform_device_count=8" \
	    $(PY) -m pytest -x -q tests/test_distributed.py tests/test_jaxcompat.py

bench:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} $(PY) -m benchmarks.run

bench-smoke:  # ~30 s benchmark smoke used by CI (kernel model + batched decode)
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} $(PY) -m benchmarks.run --quick

example-quickstart:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} $(PY) examples/quickstart.py

example-streaming:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} $(PY) examples/streaming_decode.py

example-batch:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} $(PY) examples/batch_decode.py

example-adaptive:  # planner smoke: budget -> spec -> decode (CI runs this)
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} $(PY) examples/adaptive_edge.py --budget-kb 8

serve-smoke:  # budget-driven serving path end-to-end (CI runs this)
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} $(PY) -m repro.launch.serve --budget-kb 64 --requests 4

loadtest-smoke:  # seeded load + differential oracle -> benchmarks/out/loadtest.json (CI runs this)
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} $(PY) -m repro.launch.loadtest \
	    --seed 0 --requests 16 --states 24 --stream-frac 0.25

inflight-smoke:  # inflight vs bucketed A/B at high concurrency -> benchmarks/out/inflight.json (CI runs this)
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} $(PY) -m repro.launch.loadtest \
	    --inflight --seed 0 --requests 80 --states 32 --interarrival-us 400 \
	    --inflight-slots 80

constrained-smoke:  # map-matching example (oracle-checked) + fig13 constrained bench JSON (CI runs this)
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} $(PY) examples/map_matching.py
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} $(PY) -m benchmarks.run --only fig13

test-drills:  # fault drills (worker death / mesh rescale / budget shrink) on 8 virtual devices
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} \
	    XLA_FLAGS="--xla_force_host_platform_device_count=8" \
	    $(PY) -m pytest -x -q -m drill tests/test_drills.py
