"""Hypothesis property tests for the system's invariants.

Shapes are drawn from small pools (every distinct (K, T, P) recompiles on the
single CPU core, so pools keep the jit cache warm across examples)."""

import numpy as np
import jax
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st, HealthCheck

from repro.core import (erdos_renyi_hmm, random_emissions, flash_viterbi,
                        flash_bs_viterbi, viterbi_vanilla, path_score)

_SETTINGS = dict(max_examples=12, deadline=None,
                 suppress_health_check=[HealthCheck.too_slow])


@st.composite
def problems(draw):
    K = draw(st.sampled_from([8, 24]))
    T = draw(st.sampled_from([9, 32, 57]))
    p = draw(st.sampled_from([0.3, 0.8]))
    seed = draw(st.integers(0, 2**16))
    return K, T, p, seed


def _mk(K, T, p, seed):
    k1, k2 = jax.random.split(jax.random.key(seed))
    hmm = erdos_renyi_hmm(k1, K, edge_prob=p)
    em = random_emissions(k2, T, K)
    return hmm, em


@given(problems(), st.sampled_from([1, 2, 4]))
@settings(**_SETTINGS)
def test_flash_score_equals_vanilla(prob, P):
    """INVARIANT: FLASH returns an optimal-score path for any HMM/emissions."""
    hmm, em = _mk(*prob)
    vp, vs = viterbi_vanilla(hmm.log_pi, hmm.log_A, em)
    fp, fs = flash_viterbi(hmm.log_pi, hmm.log_A, em, parallelism=P)
    assert np.allclose(float(fs), float(vs), rtol=1e-5, atol=1e-4)
    # the decoded path achieves the optimal score (tie-robust check)
    fscore = path_score(hmm.log_pi, hmm.log_A, em, fp)
    assert np.allclose(float(fscore), float(vs), rtol=1e-5, atol=1e-4)


@given(problems())
@settings(**_SETTINGS)
def test_full_beam_is_exact(prob):
    """INVARIANT: FLASH-BS with beam_width == K equals exact decoding."""
    hmm, em = _mk(*prob)
    K = em.shape[1]
    _, vs = viterbi_vanilla(hmm.log_pi, hmm.log_A, em)
    bp, bs = flash_bs_viterbi(hmm.log_pi, hmm.log_A, em, beam_width=K,
                              parallelism=2, chunk=8)
    bscore = path_score(hmm.log_pi, hmm.log_A, em, bp)
    assert np.allclose(float(bscore), float(vs), rtol=1e-5, atol=1e-4)


@given(problems())
@settings(**_SETTINGS)
def test_beam_score_upper_bounded(prob):
    """INVARIANT: any beam path's score <= the optimal score."""
    hmm, em = _mk(*prob)
    _, vs = viterbi_vanilla(hmm.log_pi, hmm.log_A, em)
    bp, _ = flash_bs_viterbi(hmm.log_pi, hmm.log_A, em, beam_width=4,
                             parallelism=2, chunk=8)
    bscore = path_score(hmm.log_pi, hmm.log_A, em, bp)
    assert float(bscore) <= float(vs) + 1e-4


@given(problems())
@settings(**_SETTINGS)
def test_path_states_in_range(prob):
    hmm, em = _mk(*prob)
    K = em.shape[1]
    path, _ = flash_viterbi(hmm.log_pi, hmm.log_A, em, parallelism=2)
    p = np.asarray(path)
    assert p.shape == (em.shape[0],)
    assert ((0 <= p) & (p < K)).all()


# -- BatchScheduler scheduling invariants ------------------------------------

from repro.serving.scheduler import BatchScheduler

_BUCKETS = (16, 64)


def _bucket_of(T):
    return 16 if T <= 16 else 64


class _ContractDecoder:
    """Fake decode_batch_fn that *enforces* the scheduler contract on every
    call — true lengths alongside the batch, payload rows intact, pad tail
    zeroed (i.e. never filled from another request, the 'decoded pad frames'
    failure) — and returns tag-coded results so cross-wired fan-out shows up
    as a wrong path, not a silent success."""

    def __call__(self, padded, lengths):
        B, Tb, _ = padded.shape
        lengths = np.asarray(lengths)
        assert lengths.shape == (B,)
        assert np.all((1 <= lengths) & (lengths <= Tb))
        tags = padded[:, 0, 0].astype(np.int64)
        for i in range(B):
            assert tags[i] > 0
            assert np.all(padded[i, :lengths[i]] == tags[i])
            assert np.all(padded[i, lengths[i]:] == 0.0)
        paths = np.repeat(tags[:, None], Tb, axis=1)
        return paths, tags.astype(np.float64)


_SCHED_ACTIONS = st.one_of(
    st.tuples(st.just("submit"), st.sampled_from([3, 12, 16, 29, 60])),
    st.just(("step",)),
    st.just(("drain",)),
)


@given(st.lists(_SCHED_ACTIONS, max_size=30))
@settings(**_SETTINGS)
def test_scheduler_exactly_once_under_interleaving(ops):
    """INVARIANTS under arbitrary submit/step/drain interleavings: every
    request completes exactly once with the result routed back to it (right
    length, right tag), pad frames are never decoded (enforced inside the
    fake decoder), and the queue is empty after the final drain."""
    sched = BatchScheduler(_ContractDecoder(), max_batch=3, buckets=_BUCKETS)
    submitted = []                       # (scheduler rid, tag, T)
    completed = []
    for op in ops:
        if op[0] == "submit":
            tag = float(len(submitted) + 1)
            req = sched.submit(np.full((op[1], 4), tag, np.float32))
            submitted.append((req.rid, tag, op[1]))
        elif op[0] == "step":
            completed.extend(sched.step())
        else:
            completed.extend(sched.drain())
    completed.extend(sched.drain())
    assert not sched.queue

    assert sorted(r.rid for r in completed) == [r for r, _, _ in submitted]
    by_rid = {rid: (tag, T) for rid, tag, T in submitted}
    for r in completed:
        tag, T = by_rid[r.rid]
        path, score = r.result
        assert r.done
        assert path.shape == (T,)
        assert np.all(path == int(tag))
        assert score == tag


@given(st.lists(_SCHED_ACTIONS, max_size=30))
@settings(**_SETTINGS)
def test_scheduler_preserves_per_bucket_order(ops):
    """INVARIANT: within a length bucket, requests complete in submission
    order, no matter how submits and steps interleave (steps pack the front
    request's bucket, skipping — but never reordering — the others)."""
    sched = BatchScheduler(_ContractDecoder(), max_batch=3, buckets=_BUCKETS)
    submitted = []
    completed = []
    for op in ops:
        if op[0] == "submit":
            tag = float(len(submitted) + 1)
            req = sched.submit(np.full((op[1], 4), tag, np.float32))
            submitted.append((req.rid, tag, op[1]))
        elif op[0] == "step":
            completed.extend(sched.step())
        else:
            completed.extend(sched.drain())
    completed.extend(sched.drain())
    for b in _BUCKETS:
        want = [rid for rid, _, T in submitted if _bucket_of(T) == b]
        got = [r.rid for r in completed if _bucket_of(len(r.payload)) == b]
        assert got == want


@given(st.integers(0, 2**16))
@settings(**_SETTINGS)
def test_emission_shift_invariance(seed):
    """INVARIANT: adding a constant to all emissions at a timestep shifts the
    score but never changes the argmax path (log-domain linearity)."""
    hmm, em = _mk(16, 32, 0.5, seed)
    p1, s1 = flash_viterbi(hmm.log_pi, hmm.log_A, em, parallelism=2)
    em2 = em.at[5].add(7.5)
    p2, s2 = flash_viterbi(hmm.log_pi, hmm.log_A, em2, parallelism=2)
    assert np.array_equal(np.asarray(p1), np.asarray(p2))
    assert np.allclose(float(s2) - float(s1), 7.5, atol=1e-3)
