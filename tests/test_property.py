"""Hypothesis property tests for the system's invariants.

Shapes are drawn from small pools (every distinct (K, T, P) recompiles on the
single CPU core, so pools keep the jit cache warm across examples)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st, HealthCheck

from repro.core import (erdos_renyi_hmm, random_emissions, flash_viterbi,
                        flash_bs_viterbi, viterbi_vanilla, path_score)
from repro.core import reference as ref

_SETTINGS = dict(max_examples=12, deadline=None,
                 suppress_health_check=[HealthCheck.too_slow])


@st.composite
def problems(draw):
    K = draw(st.sampled_from([8, 24]))
    T = draw(st.sampled_from([9, 32, 57]))
    p = draw(st.sampled_from([0.3, 0.8]))
    seed = draw(st.integers(0, 2**16))
    return K, T, p, seed


def _mk(K, T, p, seed):
    k1, k2 = jax.random.split(jax.random.key(seed))
    hmm = erdos_renyi_hmm(k1, K, edge_prob=p)
    em = random_emissions(k2, T, K)
    return hmm, em


@given(problems(), st.sampled_from([1, 2, 4]))
@settings(**_SETTINGS)
def test_flash_score_equals_vanilla(prob, P):
    """INVARIANT: FLASH returns an optimal-score path for any HMM/emissions."""
    hmm, em = _mk(*prob)
    vp, vs = viterbi_vanilla(hmm.log_pi, hmm.log_A, em)
    fp, fs = flash_viterbi(hmm.log_pi, hmm.log_A, em, parallelism=P)
    assert np.allclose(float(fs), float(vs), rtol=1e-5, atol=1e-4)
    # the decoded path achieves the optimal score (tie-robust check)
    fscore = path_score(hmm.log_pi, hmm.log_A, em, fp)
    assert np.allclose(float(fscore), float(vs), rtol=1e-5, atol=1e-4)


@given(problems())
@settings(**_SETTINGS)
def test_full_beam_is_exact(prob):
    """INVARIANT: FLASH-BS with beam_width == K equals exact decoding."""
    hmm, em = _mk(*prob)
    K = em.shape[1]
    _, vs = viterbi_vanilla(hmm.log_pi, hmm.log_A, em)
    bp, bs = flash_bs_viterbi(hmm.log_pi, hmm.log_A, em, beam_width=K,
                              parallelism=2, chunk=8)
    bscore = path_score(hmm.log_pi, hmm.log_A, em, bp)
    assert np.allclose(float(bscore), float(vs), rtol=1e-5, atol=1e-4)


@given(problems())
@settings(**_SETTINGS)
def test_beam_score_upper_bounded(prob):
    """INVARIANT: any beam path's score <= the optimal score."""
    hmm, em = _mk(*prob)
    _, vs = viterbi_vanilla(hmm.log_pi, hmm.log_A, em)
    bp, _ = flash_bs_viterbi(hmm.log_pi, hmm.log_A, em, beam_width=4,
                             parallelism=2, chunk=8)
    bscore = path_score(hmm.log_pi, hmm.log_A, em, bp)
    assert float(bscore) <= float(vs) + 1e-4


@given(problems())
@settings(**_SETTINGS)
def test_path_states_in_range(prob):
    hmm, em = _mk(*prob)
    K = em.shape[1]
    path, _ = flash_viterbi(hmm.log_pi, hmm.log_A, em, parallelism=2)
    p = np.asarray(path)
    assert p.shape == (em.shape[0],)
    assert ((0 <= p) & (p < K)).all()


@given(st.integers(0, 2**16))
@settings(**_SETTINGS)
def test_emission_shift_invariance(seed):
    """INVARIANT: adding a constant to all emissions at a timestep shifts the
    score but never changes the argmax path (log-domain linearity)."""
    hmm, em = _mk(16, 32, 0.5, seed)
    p1, s1 = flash_viterbi(hmm.log_pi, hmm.log_A, em, parallelism=2)
    em2 = em.at[5].add(7.5)
    p2, s2 = flash_viterbi(hmm.log_pi, hmm.log_A, em2, parallelism=2)
    assert np.array_equal(np.asarray(p1), np.asarray(p2))
    assert np.allclose(float(s2) - float(s1), 7.5, atol=1e-3)
