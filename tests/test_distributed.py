"""Distributed tests (8 fake devices, run in a subprocess so the forced device
count never leaks into other tests' jax runtime)."""

import json
import os
import subprocess
import sys

import pytest

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import numpy as np, jax, jax.numpy as jnp
from repro.core import erdos_renyi_hmm, random_emissions
from repro.core import reference as ref
from repro.core.distributed import make_flash_viterbi_2d, make_batched_flash_decoder
from repro.launch.mesh import make_test_mesh
from repro.launch.steps import build_cell, lower_cell
from repro.configs import get_arch
from repro.sharding.rules import SINGLE_POD_RULES
from repro.train import TrainConfig, init_train_state, make_train_step, train_state_specs
from jax.sharding import NamedSharding, PartitionSpec as P

out = {}
mesh = make_test_mesh()   # (4, 2) data x model

# 1. 2-D sharded FLASH viterbi is exact
K, T = 64, 96
k1, k2 = jax.random.split(jax.random.key(3))
hmm = erdos_renyi_hmm(k1, K, edge_prob=0.4)
em = random_emissions(k2, T, K)
dec = make_flash_viterbi_2d(mesh, T, K)
path, score = dec(hmm.log_pi, hmm.log_A, em)
npath, nscore = ref.viterbi_numpy(np.asarray(hmm.log_pi), np.asarray(hmm.log_A), np.asarray(em))
out["viterbi_2d_exact"] = bool(np.array_equal(np.asarray(path), npath)) and \
    abs(float(score) - nscore) < 1e-3 * abs(nscore)

# 2. batched decoder shards over data and is exact per sequence
bdec = make_batched_flash_decoder(mesh)
paths, scores = bdec(hmm.log_pi, hmm.log_A, jnp.stack([em] * 8))
out["viterbi_batched_exact"] = bool(np.allclose(np.asarray(scores), nscore, rtol=1e-5))

# 3. smoke train step actually runs SPMD on the test mesh (not just lowers)
cfg = get_arch("tinyllama_1_1b").SMOKE
from repro.models import build_model
model = build_model(cfg)
tcfg = TrainConfig()
with mesh:
    state = init_train_state(model, jax.random.key(0))
    specs = train_state_specs(model, SINGLE_POD_RULES, 4)
    sh = jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), specs,
                                is_leaf=lambda x: isinstance(x, P))
    state = jax.tree_util.tree_map(jax.device_put, state, sh)
    from repro.optim.adamw import AdamWConfig
    tcfg = TrainConfig(opt=AdamWConfig(lr=5e-3, warmup_steps=1, total_steps=100))
    step = jax.jit(make_train_step(model, tcfg), donate_argnums=0)
    kt = jax.random.key(1)
    batch = {"tokens": jax.random.randint(kt, (8, 16), 0, cfg.vocab),
             "labels": jax.random.randint(kt, (8, 16), 0, cfg.vocab),
             "mask": jnp.ones((8, 16))}
    batch = jax.device_put(batch, NamedSharding(mesh, P("data", None)))
    losses = []
    for _ in range(6):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    out["spmd_train_losses_finite"] = all(np.isfinite(l) for l in losses)
    out["spmd_train_loss_decreases"] = losses[-1] < losses[0]

# 4. dry-run cell lowers+compiles on the 8-device mesh for a non-trivial arch
with mesh:
    cell = build_cell(get_arch("gemma_2b"), "decode_32k", mesh)
    compiled = lower_cell(cell).compile()
    out["gemma_decode_compiles"] = compiled is not None

print("RESULT " + json.dumps(out))
"""


@pytest.fixture(scope="module")
def results():
    env = dict(os.environ, PYTHONPATH=_SRC)
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-4000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT ")][-1]
    return json.loads(line[len("RESULT "):])


def test_viterbi_2d_exact(results):
    assert results["viterbi_2d_exact"]


def test_viterbi_batched_exact(results):
    assert results["viterbi_batched_exact"]


def test_spmd_train_step_runs_and_learns(results):
    assert results["spmd_train_losses_finite"]
    assert results["spmd_train_loss_decreases"]


def test_dryrun_cell_compiles_on_test_mesh(results):
    assert results["gemma_decode_compiles"]
