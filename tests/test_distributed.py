"""Distributed tests (8 fake devices, run in a subprocess so the forced device
count never leaks into other tests' jax runtime).

Everything here must collect and pass on the pinned jax 0.4.x toolchain AND
current jax — mesh construction and every shard_map goes through
`repro.runtime.jaxcompat`.  CI runs this file in a dedicated step with
``--xla_force_host_platform_device_count=8`` (``make test-dist``)."""

import json
import os
import subprocess
import sys

import pytest

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import numpy as np, jax, jax.numpy as jnp
from repro.core import (erdos_renyi_hmm, random_emissions, viterbi_decode,
                        viterbi_decode_batch)
from repro.core import reference as ref
from repro.core.distributed import make_flash_viterbi_2d, make_batched_flash_decoder
from repro.launch.mesh import make_test_mesh, data_axis_size
from repro.launch.steps import build_cell, lower_cell
from repro.configs import get_arch
from repro.sharding.rules import SINGLE_POD_RULES
from repro.train import TrainConfig, init_train_state, make_train_step, train_state_specs
from jax.sharding import NamedSharding, PartitionSpec as P

out = {}

# 0. mesh construction through the compat shim on stock jax (this was the
#    import-time regression: jax.sharding.AxisType does not exist on 0.4.x)
mesh = make_test_mesh()   # (4, 2) data x model
out["mesh_import_and_build"] = (len(mesh.devices.ravel()) == 8 and
                                data_axis_size(mesh) == 4)
mesh_mp = make_test_mesh(multi_pod=True)
out["mesh_multipod_build"] = dict(mesh_mp.shape) == {"pod": 2, "data": 2,
                                                     "model": 2}

# 1. 2-D sharded FLASH viterbi is exact in both model-axis layouts, and the
#    row/col layouts agree with each other
K, T = 64, 96
k1, k2 = jax.random.split(jax.random.key(3))
hmm = erdos_renyi_hmm(k1, K, edge_prob=0.4)
em = random_emissions(k2, T, K)
npath, nscore = ref.viterbi_numpy(np.asarray(hmm.log_pi), np.asarray(hmm.log_A), np.asarray(em))
paths2d = {}
for shard in ("row", "col"):
    dec = make_flash_viterbi_2d(mesh, T, K, shard=shard)
    path, score = dec(hmm.log_pi, hmm.log_A, em)
    paths2d[shard] = np.asarray(path)
    out[f"viterbi_2d_{shard}_exact"] = bool(np.array_equal(np.asarray(path), npath)) and \
        abs(float(score) - nscore) < 1e-3 * abs(nscore)
out["viterbi_2d_row_col_agree"] = bool(np.array_equal(paths2d["row"], paths2d["col"]))

# 2. sharded ragged batched decode is bit-identical to looped unbatched
#    decodes, for every serving method
B, TMAX = 8, 40
lengths = np.array([TMAX, 17, 1, 33, TMAX, 9, 25, 2], np.int32)
emb = random_emissions(jax.random.key(7), B * TMAX, K).reshape(B, TMAX, K)
for method in ("vanilla", "flash", "fused"):
    bdec = make_batched_flash_decoder(mesh, method=method)
    paths, scores = bdec(hmm.log_pi, hmm.log_A, emb, jnp.asarray(lengths))
    ok = True
    for i, L in enumerate(lengths):
        p, s = viterbi_decode(emb[i, :int(L)], hmm.log_pi, hmm.log_A,
                              method="vanilla")
        ok = ok and bool(np.array_equal(np.asarray(paths[i, :int(L)]),
                                        np.asarray(p)))
        ok = ok and bool(np.isclose(float(scores[i]), float(s), rtol=1e-6))
    out[f"batched_{method}_ragged_bit_identical"] = ok

# 3. viterbi_decode_batch(mesh=...) is bit-identical to the single-device call
ps, ss = viterbi_decode_batch(emb, hmm.log_pi, hmm.log_A, jnp.asarray(lengths),
                              method="flash", mesh=mesh)
p0, s0 = viterbi_decode_batch(emb, hmm.log_pi, hmm.log_A, jnp.asarray(lengths),
                              method="flash")
out["sharded_batch_bit_identical"] = bool(np.array_equal(np.asarray(ps), np.asarray(p0))) \
    and bool(np.array_equal(np.asarray(ss), np.asarray(s0)))

# 4. serving alignment head shards a non-divisible bucket (pads with dummies)
from repro.serving.alignment import AlignmentConfig, make_alignment_head
head = make_alignment_head(hmm.log_pi, hmm.log_A,
                           AlignmentConfig(method="flash"), mesh=mesh)
ems5 = emb[:5]
lens5 = jnp.asarray(lengths[:5])
hp, hs = head(ems5, lens5)
ok = hp.shape == (5, TMAX) and hs.shape == (5,)
for i in range(5):
    L = int(lengths[i])
    p, s = viterbi_decode(emb[i, :L], hmm.log_pi, hmm.log_A, method="flash",
                          lanes=None)
    ok = ok and bool(np.array_equal(np.asarray(hp[i, :L]), np.asarray(p)))
    ok = ok and bool(np.isclose(float(hs[i]), float(s), rtol=1e-6))
out["alignment_head_sharded_exact"] = ok

# 5. smoke train step actually runs SPMD on the test mesh (not just lowers)
cfg = get_arch("tinyllama_1_1b").SMOKE
from repro.models import build_model
model = build_model(cfg)
tcfg = TrainConfig()
with mesh:
    state = init_train_state(model, jax.random.key(0))
    specs = train_state_specs(model, SINGLE_POD_RULES, 4)
    sh = jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), specs,
                                is_leaf=lambda x: isinstance(x, P))
    state = jax.tree_util.tree_map(jax.device_put, state, sh)
    from repro.optim.adamw import AdamWConfig
    tcfg = TrainConfig(opt=AdamWConfig(lr=5e-3, warmup_steps=1, total_steps=100))
    step = jax.jit(make_train_step(model, tcfg), donate_argnums=0)
    kt = jax.random.key(1)
    batch = {"tokens": jax.random.randint(kt, (8, 16), 0, cfg.vocab),
             "labels": jax.random.randint(kt, (8, 16), 0, cfg.vocab),
             "mask": jnp.ones((8, 16))}
    batch = jax.device_put(batch, NamedSharding(mesh, P("data", None)))
    losses = []
    for _ in range(6):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    out["spmd_train_losses_finite"] = all(np.isfinite(l) for l in losses)
    out["spmd_train_loss_decreases"] = losses[-1] < losses[0]

# 6. dry-run cell lowers+compiles on the 8-device mesh for a non-trivial arch
with mesh:
    cell = build_cell(get_arch("gemma_2b"), "decode_32k", mesh)
    compiled = lower_cell(cell).compile()
    out["gemma_decode_compiles"] = compiled is not None

print("RESULT " + json.dumps(out))
"""


@pytest.fixture(scope="module")
def results():
    env = dict(os.environ, PYTHONPATH=_SRC)
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-4000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT ")][-1]
    return json.loads(line[len("RESULT "):])


def test_mesh_builds_on_stock_jax(results):
    """Regression: launch/mesh.py imports + builds meshes on jax 0.4.x."""
    assert results["mesh_import_and_build"]
    assert results["mesh_multipod_build"]


def test_viterbi_2d_exact(results):
    assert results["viterbi_2d_row_exact"]
    assert results["viterbi_2d_col_exact"]


def test_viterbi_2d_row_col_agree(results):
    assert results["viterbi_2d_row_col_agree"]


@pytest.mark.parametrize("method", ["vanilla", "flash", "fused"])
def test_batched_ragged_bit_identical(results, method):
    """Sharded ragged batch == looped unbatched decodes, bit for bit."""
    assert results[f"batched_{method}_ragged_bit_identical"]


def test_sharded_batch_matches_single_device(results):
    """viterbi_decode_batch(mesh=...) == viterbi_decode_batch() exactly."""
    assert results["sharded_batch_bit_identical"]


def test_alignment_head_sharded(results):
    assert results["alignment_head_sharded_exact"]


def test_spmd_train_step_runs_and_learns(results):
    assert results["spmd_train_losses_finite"]
    assert results["spmd_train_loss_decreases"]


def test_dryrun_cell_compiles_on_test_mesh(results):
    assert results["gemma_decode_compiles"]
