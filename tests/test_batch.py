"""Batched-vs-looped equivalence: `viterbi_decode_batch` with ragged lengths
must be bit-identical per sequence to a Python loop of `viterbi_decode` calls
(exact methods; flash_bs is run at beam_width=K where it is exact)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (erdos_renyi_hmm, random_emissions, chunked_vmap,
                        flash_viterbi, flash_bs_viterbi,
                        viterbi_decode, viterbi_decode_batch, BATCH_METHODS)

K, TMAX = 32, 40
LENGTHS = np.array([TMAX, 17, 1, 33, TMAX], np.int32)  # ragged incl. T=1, max
METHOD_KW = {
    "vanilla": {},
    "fused": {},
    "flash": dict(parallelism=4),
    "flash_bs": dict(parallelism=4, beam_width=K, chunk=16),
}


@pytest.fixture(scope="module")
def batch_problem():
    key = jax.random.key(123)
    k1, k2 = jax.random.split(key)
    hmm = erdos_renyi_hmm(k1, K, edge_prob=0.4)
    em = random_emissions(k2, len(LENGTHS) * TMAX, K).reshape(
        len(LENGTHS), TMAX, K)
    return hmm, em


def _assert_matches_loop(hmm, em, lengths, method, **kw):
    paths, scores = viterbi_decode_batch(em, hmm.log_pi, hmm.log_A, lengths,
                                         method=method, **kw)
    assert paths.shape == em.shape[:2] and paths.dtype == jnp.int32
    assert scores.shape == (em.shape[0],)
    for i, L in enumerate(lengths):
        p, s = viterbi_decode(em[i, :int(L)], hmm.log_pi, hmm.log_A,
                              method=method, **kw)
        assert np.array_equal(np.asarray(paths[i, :int(L)]), np.asarray(p)), \
            (method, i)
        assert np.isclose(float(scores[i]), float(s), rtol=1e-6, atol=0), \
            (method, i)


@pytest.mark.parametrize("method", BATCH_METHODS)
def test_batch_matches_loop_ragged(batch_problem, method):
    hmm, em = batch_problem
    _assert_matches_loop(hmm, em, LENGTHS, method, **METHOD_KW[method])


@pytest.mark.parametrize("method", ["vanilla", "fused"])
def test_batch_all_equal_lengths_and_default(batch_problem, method):
    hmm, em = batch_problem
    equal = np.full((em.shape[0],), TMAX, np.int32)
    _assert_matches_loop(hmm, em, equal, method)
    # lengths=None means full length — same result as explicit lengths
    p0, s0 = viterbi_decode_batch(em, hmm.log_pi, hmm.log_A, method=method)
    p1, s1 = viterbi_decode_batch(em, hmm.log_pi, hmm.log_A, equal,
                                  method=method)
    assert np.array_equal(np.asarray(p0), np.asarray(p1))
    assert np.array_equal(np.asarray(s0), np.asarray(s1))


@pytest.mark.parametrize("method", BATCH_METHODS)
def test_batch_T1_edge(batch_problem, method):
    hmm, em = batch_problem
    em1 = em[:, :1]
    paths, scores = viterbi_decode_batch(em1, hmm.log_pi, hmm.log_A,
                                         method=method, **METHOD_KW[method])
    for i in range(em1.shape[0]):
        p, s = viterbi_decode(em1[i], hmm.log_pi, hmm.log_A, method="vanilla")
        assert np.array_equal(np.asarray(paths[i]), np.asarray(p))
        assert np.isclose(float(scores[i]), float(s), rtol=1e-6)


def test_batch_pad_tail_repeats_final_state(batch_problem):
    hmm, em = batch_problem
    paths, _ = viterbi_decode_batch(em, hmm.log_pi, hmm.log_A, LENGTHS,
                                    method="fused")
    for i, L in enumerate(LENGTHS):
        tail = np.asarray(paths[i, int(L):])
        assert np.all(tail == np.asarray(paths[i, int(L) - 1]))


def test_batch_unknown_method_raises(batch_problem):
    hmm, em = batch_problem
    with pytest.raises(ValueError):
        viterbi_decode_batch(em, hmm.log_pi, hmm.log_A, method="nope")


@pytest.mark.parametrize("bad", [[0, 17, 33, 1, 5], [1, TMAX + 1, 3, 4, 5],
                                 [-2, 1, 1, 1, 1]])
def test_batch_lengths_out_of_range_raise(batch_problem, bad):
    """No silent clipping: concrete lengths outside [1, T] raise eagerly
    instead of decoding the wrong frame span."""
    hmm, em = batch_problem
    with pytest.raises(ValueError, match="lengths must lie"):
        viterbi_decode_batch(em, hmm.log_pi, hmm.log_A,
                             np.asarray(bad, np.int32), method="vanilla")


def test_batch_traced_lengths_still_jit(batch_problem):
    """Valid lengths under jit (tracers) pass through the validation."""
    hmm, em = batch_problem

    @jax.jit
    def f(e, ln):
        return viterbi_decode_batch(e, hmm.log_pi, hmm.log_A, ln,
                                    method="vanilla")

    p0, s0 = viterbi_decode_batch(em, hmm.log_pi, hmm.log_A, LENGTHS,
                                  method="vanilla")
    p1, s1 = f(em, jnp.asarray(LENGTHS))
    assert np.array_equal(np.asarray(p0), np.asarray(p1))
    assert np.array_equal(np.asarray(s0), np.asarray(s1))


def test_batch_pad_frames_do_not_leak(batch_problem):
    """Garbage in the pad frames must not change any result (the scheduler
    zero-pads, but the contract is 'anything')."""
    hmm, em = batch_problem
    em_dirty = np.array(em)
    for i, L in enumerate(LENGTHS):
        em_dirty[i, int(L):] = 1e3
    clean = viterbi_decode_batch(em, hmm.log_pi, hmm.log_A, LENGTHS,
                                 method="fused")
    dirty = viterbi_decode_batch(jnp.asarray(em_dirty), hmm.log_pi,
                                 hmm.log_A, LENGTHS, method="fused")
    assert np.array_equal(np.asarray(clean[0]), np.asarray(dirty[0]))
    assert np.array_equal(np.asarray(clean[1]), np.asarray(dirty[1]))


# ---------------------------------------------------------------------------
# chunked_vmap remainder handling (odd lane counts)
# ---------------------------------------------------------------------------

def test_chunked_vmap_remainder():
    xs = jnp.arange(7.0)
    out = chunked_vmap(lambda x: x * 2, (xs,), lanes=3)  # 7 = 2*3 + 1
    assert np.array_equal(np.asarray(out), np.asarray(xs) * 2)


@pytest.mark.parametrize("lanes", [3, 5])
def test_flash_odd_lanes(batch_problem, lanes):
    hmm, em = batch_problem
    e = em[0]
    p_ref, s_ref = flash_viterbi(hmm.log_pi, hmm.log_A, e, parallelism=8,
                                 lanes=None)
    p, s = flash_viterbi(hmm.log_pi, hmm.log_A, e, parallelism=8, lanes=lanes)
    assert np.array_equal(np.asarray(p), np.asarray(p_ref))
    assert float(s) == float(s_ref)


def test_flash_bs_odd_lanes(batch_problem):
    hmm, em = batch_problem
    e = em[0]
    p_ref, s_ref = flash_bs_viterbi(hmm.log_pi, hmm.log_A, e, beam_width=K,
                                    parallelism=8, lanes=None, chunk=16)
    p, s = flash_bs_viterbi(hmm.log_pi, hmm.log_A, e, beam_width=K,
                            parallelism=8, lanes=3, chunk=16)
    assert np.array_equal(np.asarray(p), np.asarray(p_ref))
    assert float(s) == float(s_ref)
