"""Tests for the flashprove tier (`repro.analysis` tier 2): the planner-model
vs jaxpr-liveness property over every registered spec, injected-defect
negatives (an f64 promotion, an oversized Pallas tile config), the collective
walk's positive control, and the waiver grammar."""

from __future__ import annotations

import sys
import types

import jax
import jax.numpy as jnp
import pytest

from repro.analysis.collective_check import (check_collectives,
                                             collectives_in)
from repro.analysis.findings import (Finding, ProveReport, apply_waivers,
                                     collect_waivers)
from repro.analysis.jaxpr_check import (analyze_jaxpr, batch_entry_jaxpr,
                                        dp_state_bytes, entry_jaxpr,
                                        jaxpr_flops, jaxpr_peak_temp_bytes)
from repro.analysis.pallas_check import (DEFAULT_VMEM_BUDGET, BlockInfo,
                                         _alignment_findings, _check_entry,
                                         harvest_pallas_calls)
from repro.core.planner import crosscheck_state_bytes
from repro.core.spec import SPEC_BY_METHOD

# small grid: the property is checked exhaustively (deep grids, K=128 Pallas
# points) by `make analysis-deep`; tier-1 keeps the trace cost bounded.
GRID = ((16, 32), (24, 64))
BATCH_GRID = ((16, 32, 3),)


# ---------------------------------------------------------------------------
# The PV104 property: planner model upper-bounds IR-derived DP state
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", sorted(SPEC_BY_METHOD))
def test_model_upper_bounds_ir_state(method):
    spec = SPEC_BY_METHOD[method]()
    for K, T in GRID:
        # zero is legitimate for a streaming surrogate whose only stateful
        # output is the jaxpr boundary itself (e.g. `online`'s chunk step).
        ir = jaxpr_peak_temp_bytes(spec, K, T)
        msg = crosscheck_state_bytes(spec, K, T, ir)
        assert msg is None, msg


@pytest.mark.parametrize("method", sorted(
    m for m, cls in SPEC_BY_METHOD.items() if cls.batch_method is not None))
def test_model_upper_bounds_ir_state_batched(method):
    spec = SPEC_BY_METHOD[method]()
    for K, T, B in BATCH_GRID:
        ir = dp_state_bytes(batch_entry_jaxpr(spec, K, T, B))
        msg = crosscheck_state_bytes(spec, K, T, ir, batch=B)
        assert msg is None, msg


def test_ir_flops_scale_with_sequence_length():
    spec = SPEC_BY_METHOD["vanilla"]()
    f1, f2 = jaxpr_flops(spec, 16, 32), jaxpr_flops(spec, 16, 128)
    assert 0 < f1 < f2


def test_crosscheck_rejects_an_ir_blowup():
    # a decoder whose IR retains far more than the model says is a finding,
    # not a tolerance: the message names the method and both sides.
    spec = SPEC_BY_METHOD["vanilla"]()
    msg = crosscheck_state_bytes(spec, 16, 32, ir_bytes=1 << 30)
    assert msg is not None and "vanilla" in msg


# ---------------------------------------------------------------------------
# Injected defects the jaxpr pass must flag
# ---------------------------------------------------------------------------

def test_injected_f64_promotion_is_flagged():
    with jax.experimental.enable_x64():
        closed = jax.make_jaxpr(
            lambda x: x.astype(jnp.float64) * 2.0)(jnp.ones((8,), jnp.float32))
        _, findings = analyze_jaxpr(closed, "jaxpr:injected", 1 << 20)
    assert "PV101" in {f.code for f in findings}


def test_injected_bf16_widening_is_flagged():
    closed = jax.make_jaxpr(
        lambda x: x.astype(jnp.float32) + 1.0)(jnp.ones((8,), jnp.bfloat16))
    _, findings = analyze_jaxpr(closed, "jaxpr:injected", 1 << 20)
    assert "PV101" in {f.code for f in findings}


def test_narrowing_is_not_a_widening():
    closed = jax.make_jaxpr(
        lambda x: x.astype(jnp.bfloat16))(jnp.ones((8,), jnp.float32))
    _, findings = analyze_jaxpr(closed, "jaxpr:injected", 1 << 20)
    assert not findings


def test_host_callback_is_flagged():
    def f(x):
        jax.debug.print("x={x}", x=x)
        return x * 2.0

    closed = jax.make_jaxpr(f)(jnp.ones((4,), jnp.float32))
    _, findings = analyze_jaxpr(closed, "jaxpr:injected", 1 << 20)
    assert "PV102" in {f.code for f in findings}


def test_oversized_intermediate_is_flagged():
    closed = jax.make_jaxpr(
        lambda a, b: (a[:, None, :] + b[None, :, :]).sum()
    )(jnp.ones((256, 256), jnp.float32), jnp.ones((256, 256), jnp.float32))
    # (256, 256, 256) f32 broadcast = 64 MiB, far above a 1 KiB model.
    _, findings = analyze_jaxpr(closed, "jaxpr:injected", 1024)
    assert "PV103" in {f.code for f in findings}


# ---------------------------------------------------------------------------
# Pallas pass: tile alignment + the oversized-config rejection
# ---------------------------------------------------------------------------

def test_oversized_tile_config_is_rejected():
    # the raw kernel bypasses `ops._kernel_fits`' runtime fallback, so the
    # static pass is the only guard: K=2048 makes the resident transition
    # block (K, K) f32 = 16 MiB > the 12 MiB budget.
    from repro.kernels import viterbi_dp

    K, bt, B = 2048, 8, 2
    A = jnp.zeros((K, K), jnp.float32)
    em = jnp.zeros((B, 4 * bt, K), jnp.float32)
    d0 = jnp.zeros((B, K), jnp.float32)
    report = ProveReport()
    _check_entry(
        "pallas:test.oversized",
        lambda: viterbi_dp.viterbi_forward_batch(A, em, d0, bt=bt,
                                                 interpret=True),
        DEFAULT_VMEM_BUDGET, report)
    assert "PV202" in {f.code for f in report.findings}


def test_harvest_reads_declared_blocks_back():
    from repro.kernels import viterbi_dp

    K, bt, B = 128, 8, 2
    A = jnp.zeros((K, K), jnp.float32)
    em = jnp.zeros((B, 4 * bt, K), jnp.float32)
    d0 = jnp.zeros((B, K), jnp.float32)
    closed = jax.make_jaxpr(
        lambda: viterbi_dp.viterbi_forward_batch(A, em, d0, bt=bt,
                                                 interpret=True))()
    (summary,) = harvest_pallas_calls(closed)
    assert summary.grid
    shapes = {b.block_shape for b in summary.blocks}
    assert (K, K) in shapes              # resident transition block
    assert summary.vmem_bytes <= DEFAULT_VMEM_BUDGET


def test_alignment_rule_and_its_exemptions():
    def block(bs, arr):
        return BlockInfo(label="in[0]", block_shape=bs, array_shape=arr,
                         dtype="float32", streamed=False)

    # off-grid lane dim that is not the full axis -> PV201
    assert [f.code for f in _alignment_findings(
        "pallas:t", block((8, 72), (64, 1024)))] == ["PV201"]
    # full-axis lane dim is the data's own shape, not the blocking's
    assert _alignment_findings("pallas:t", block((8, 72), (64, 72))) == []
    # sublane 1 is the squeeze/batch-axis idiom
    assert _alignment_findings("pallas:t", block((1, 128), (64, 1024))) == []
    # aligned tiles are silent
    assert _alignment_findings("pallas:t", block((8, 128), (64, 1024))) == []


# ---------------------------------------------------------------------------
# Collective walk: negative on the tree, positive control for the detector
# ---------------------------------------------------------------------------

def test_sharded_decode_has_no_collectives():
    report = check_collectives(quick=True)
    assert report.ok, [str(f) for f in report.findings]
    assert report.checks


def test_collective_detector_positive_control():
    # psum binds the same equation on a 1-device axis, so the detector must
    # see a deliberately-inserted collective even on the CPU lint host.
    from jax.sharding import PartitionSpec as P

    from repro.runtime.jaxcompat import make_mesh, shard_map

    mesh = make_mesh((1,), ("data",))
    f = shard_map(lambda v: jax.lax.psum(v, "data"), mesh=mesh,
                  in_specs=P("data"), out_specs=P())
    closed = jax.make_jaxpr(f)(jnp.ones((4,), jnp.float32))
    assert any(name.startswith("psum") for name in collectives_in(closed))


# ---------------------------------------------------------------------------
# Waiver grammar
# ---------------------------------------------------------------------------

def test_waiver_prefix_matching_and_unused_detection():
    f = Finding("PV103", "jaxpr:flash:batch[K=16,T=32,B=3]", "big broadcast")
    active, waived = apply_waivers([f], {"PV103:jaxpr:flash": "modeled cost"})
    assert active == [] and waived == [(f, "modeled cost")]

    # wrong code does not match; the unused waiver itself becomes PV000
    active, waived = apply_waivers([f], {"PV101:jaxpr:flash": "nope"})
    assert [g.code for g in active] == ["PV103", "PV000"] and not waived

    # narrowed runs must not flag deep-only waivers
    active, _ = apply_waivers([f], {"PV101:jaxpr:flash": "nope"},
                              require_used=False)
    assert [g.code for g in active] == ["PV103"]


def test_malformed_waivers_are_pv000():
    mod = types.ModuleType("fake_waiver_mod")
    mod.FLASHPROVE_WAIVERS = {
        "PV999:x": "unknown code",
        "PV103:y": "   ",          # empty reason
        "PV000:z": "cannot waive the waiver rule",
    }
    sys.modules["fake_waiver_mod"] = mod
    try:
        waivers, malformed = collect_waivers(("fake_waiver_mod",))
    finally:
        del sys.modules["fake_waiver_mod"]
    assert waivers == {}
    assert [m.code for m in malformed] == ["PV000"] * 3


def test_tree_waivers_are_well_formed():
    # every in-code triage declaration parses; zero malformed at merge
    waivers, malformed = collect_waivers()
    assert malformed == []
    assert waivers, "the triaged findings declare their waivers in-code"


def test_entry_jaxpr_covers_streaming_specs():
    # the streaming specs trace their chunk-advance surrogates — the pass
    # never silently skips a registered method.
    for method in ("online", "online_beam"):
        closed = entry_jaxpr(SPEC_BY_METHOD[method](), 16, 64)
        assert closed.jaxpr.eqns
