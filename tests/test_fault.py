"""Direct unit tests for the fault-tolerance primitives.

`HeartbeatMonitor` and `StragglerDetector` are the pure-logic half of the
fault runtime — the drills in test_drills.py exercise them end-to-end, these
pin the edge cases (0 workers, all dead, even-length median windows, window
eviction) with an injected clock."""

import numpy as np
import pytest

from repro.runtime.fault import HeartbeatMonitor, StragglerDetector


class FakeClock:
    def __init__(self, t=0.0):
        self.t = t

    def now(self):
        return self.t


# ---------------------------------------------------------------------------
# HeartbeatMonitor
# ---------------------------------------------------------------------------

def test_heartbeat_zero_workers_is_healthy():
    clock = FakeClock()
    mon = HeartbeatMonitor(num_workers=0, timeout_s=1.0, clock=clock.now)
    clock.t = 100.0
    assert mon.dead_workers() == []
    assert mon.healthy()


def test_heartbeat_all_dead():
    clock = FakeClock()
    mon = HeartbeatMonitor(num_workers=3, timeout_s=5.0, clock=clock.now)
    clock.t = 5.0 + 1e-6
    assert mon.dead_workers() == [0, 1, 2]
    assert not mon.healthy()


def test_heartbeat_boundary_is_alive():
    """A worker seen exactly `timeout_s` ago is still alive (strict >)."""
    clock = FakeClock()
    mon = HeartbeatMonitor(num_workers=1, timeout_s=5.0, clock=clock.now)
    clock.t = 5.0
    assert mon.healthy()


def test_heartbeat_beat_revives_only_that_worker():
    clock = FakeClock()
    mon = HeartbeatMonitor(num_workers=2, timeout_s=2.0, clock=clock.now)
    clock.t = 3.0
    mon.beat(0)
    assert mon.dead_workers() == [1]
    clock.t = 4.9
    assert mon.dead_workers() == [1]
    clock.t = 5.1
    assert mon.dead_workers() == [0, 1]


# ---------------------------------------------------------------------------
# StragglerDetector
# ---------------------------------------------------------------------------

def test_median_odd_window():
    det = StragglerDetector(num_workers=1)
    for t in (3.0, 1.0, 2.0):
        det.record(0, t)
    assert det.median() == 2.0


def test_median_even_window_is_true_median():
    """Even-length windows must average the two middle elements, not take
    the upper one — the upper-middle bias inflated the straggler threshold."""
    det = StragglerDetector(num_workers=1)
    for t in (1.0, 2.0, 3.0, 10.0):
        det.record(0, t)
    assert det.median() == pytest.approx(2.5)
    assert det.median() == pytest.approx(np.median([1.0, 2.0, 3.0, 10.0]))


def test_median_empty():
    det = StragglerDetector(num_workers=2)
    assert det.median() == 0.0
    assert det.stragglers() == []


def test_straggler_flagged_and_released():
    det = StragglerDetector(num_workers=2, factor=3.0, window=16)
    for _ in range(8):
        det.record(0, 1.0)
        det.record(1, 1.0)
    det.record(1, 10.0)
    assert det.stragglers() == [1]
    det.record(1, 1.0)  # back to normal on its next step
    assert det.stragglers() == []


def test_straggler_even_window_regression():
    """History [1, 1, 2, 5]: the true median is 1.5 (threshold 4.5), so the
    5.0 step is a straggler.  The old upper-middle 'median' said 2.0
    (threshold 6.0) and masked it."""
    det = StragglerDetector(num_workers=2, factor=3.0)
    for t in (1.0, 1.0, 2.0):
        det.record(0, t)
    det.record(1, 5.0)
    assert det.median() == pytest.approx(1.5)
    assert det.median() == pytest.approx(np.median([1.0, 1.0, 2.0, 5.0]))
    assert det.stragglers() == [1]


def test_window_eviction():
    """Old samples fall out of the rolling window: an early spike regime must
    stop dominating the median once `window * num_workers` newer samples
    arrive."""
    det = StragglerDetector(num_workers=1, factor=3.0, window=4)
    for _ in range(4):
        det.record(0, 100.0)
    assert det.median() == 100.0
    for _ in range(4):  # exactly window*num_workers fresh samples
        det.record(0, 1.0)
    assert det.median() == 1.0
    assert len(det.history) == 4
    det.record(0, 10.0)
    assert det.stragglers() == [0]
