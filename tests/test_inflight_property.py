"""Hypothesis property suite for the inflight serving tier.

Randomized session mixes — lengths, lags, feed granularities, priorities,
budgets — against a fixed 3-slot pool, asserting the same invariants
`test_inflight.py` pins deterministically: oracle bit-identity, exactly-once
collection, admission under budget, leak-free slot reuse.

One pool shape (S=3, block=8, K=24) across all examples keeps the jit cache
warm (see `test_property.py`); everything random is array *contents* and
schedule order."""

import numpy as np
import jax
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st, HealthCheck

from repro.core import (ResourceBudget, erdos_renyi_hmm, random_emissions,
                        online_session_bytes)
from repro.serving import InflightScheduler

_SETTINGS = dict(max_examples=10, deadline=None,
                 suppress_health_check=[HealthCheck.too_slow])


@pytest.fixture(scope="module")
def hmm():
    return erdos_renyi_hmm(jax.random.key(7), 24, edge_prob=0.4)


def _ems(hmm, lengths, seed=0, scale=2.0):
    key = jax.random.key(seed)
    return [np.asarray(random_emissions(k, T, hmm.log_pi.shape[0],
                                        scale=scale))
            for k, T in zip(jax.random.split(key, len(lengths)), lengths)]


@st.composite
def schedules(draw):
    n = draw(st.integers(2, 4))
    lengths = [draw(st.sampled_from([7, 18, 33, 49])) for _ in range(n)]
    lags = [draw(st.sampled_from([None, 4, 16])) for _ in range(n)]
    feeds = [draw(st.sampled_from([3, 8, 13, 64])) for _ in range(n)]
    prios = [draw(st.integers(0, 1)) for _ in range(n)]
    seed = draw(st.integers(0, 2**16))
    budgeted = draw(st.booleans())
    return lengths, lags, feeds, prios, seed, budgeted


@given(schedules())
@settings(**_SETTINGS)
def test_property_random_schedules(hmm, sched_draw):
    """INVARIANTS under random session mixes on a shared 3-slot pool:
    bit-identity to each session's oracle, exactly-once collection,
    admission never exceeding the budget, slot reuse leak-free."""
    lengths, lags, feeds, prios, seed, budgeted = sched_draw
    cap = (online_session_bytes(24, 8, max_lag=64) * 2 if budgeted else None)
    budget = ResourceBudget(memory_bytes=cap) if cap else None
    sched = InflightScheduler(hmm.log_pi, hmm.log_A, max_slots=3, block=8,
                              budget=budget)
    ems = _ems(hmm, lengths, seed=seed, scale=0.5)
    sids, cursors, collected = [], [0] * len(ems), {}
    for lag, prio in zip(lags, prios):
        sid = sched.submit(max_lag=lag, priority=prio)
        sids.append(sid)
        collected[sid] = []
    while any(c < e.shape[0] for c, e in zip(cursors, ems)):
        for i, sid in enumerate(sids):
            c, em = cursors[i], ems[i]
            if c < em.shape[0]:
                sched.feed(sid, em[c:c + feeds[i]])
                cursors[i] = min(c + feeds[i], em.shape[0])
        sched.pump()
        if cap is not None:
            assert sched.admitted_bytes() <= cap
        for sid in sids:
            seg = sched.collect(sid)
            if seg.shape[0]:
                collected[sid].append(seg)
    for sid, em in zip(sids, ems):
        path, score = sched.finish(sid)
        tail = sched.collect(sid)
        if tail.shape[0]:
            collected[sid].append(tail)
        assert sched.collect(sid).shape[0] == 0
        delivered = (np.concatenate(collected[sid]) if collected[sid]
                     else np.zeros((0,), np.int32))
        assert np.array_equal(delivered, path)      # exactly-once, in order
        ref_path, ref_score = sched.session_spec(sid).run(
            hmm.log_pi, hmm.log_A, em)
        assert np.array_equal(path, np.asarray(ref_path))
        assert float(score) == float(ref_score)
    assert sched.admitted_bytes() == 0
    assert len(sched._free) == 3                    # every slot released
