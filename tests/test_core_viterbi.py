"""Correctness of the full Viterbi decoder family vs numpy references and
brute force.  Keep the number of distinct jit shapes small (1 CPU core)."""

import numpy as np
import jax
import pytest

from repro.core import (erdos_renyi_hmm, left_to_right_hmm, random_emissions,
                        sample_observations, path_score,
                        viterbi_vanilla, viterbi_checkpoint, flash_viterbi,
                        flash_bs_viterbi, beam_static_viterbi,
                        beam_static_mp_viterbi, viterbi_assoc, viterbi_decode)
from repro.core import reference as ref


@pytest.fixture(scope="module")
def problem():
    key = jax.random.key(42)
    k1, k2 = jax.random.split(key)
    hmm = erdos_renyi_hmm(k1, 48, edge_prob=0.3)
    em = random_emissions(k2, 96, 48)
    npath, nscore = ref.viterbi_numpy(np.asarray(hmm.log_pi),
                                      np.asarray(hmm.log_A), np.asarray(em))
    return hmm, em, npath, nscore


def _check_exact(problem, path, score):
    hmm, em, npath, nscore = problem
    assert np.allclose(float(score), nscore, rtol=1e-5)
    ps = ref.path_score_numpy(np.asarray(hmm.log_pi), np.asarray(hmm.log_A),
                              np.asarray(em), np.asarray(path))
    assert np.allclose(ps, nscore, rtol=1e-5)   # decoded path is optimal
    assert np.array_equal(np.asarray(path), npath)


def test_brute_force_tiny():
    key = jax.random.key(7)
    k1, k2 = jax.random.split(key)
    hmm = erdos_renyi_hmm(k1, 4, num_obs=5, edge_prob=0.7)
    em = random_emissions(k2, 5, 4)
    bf_path, bf_score = ref.brute_force(np.asarray(hmm.log_pi),
                                        np.asarray(hmm.log_A), np.asarray(em))
    path, score = viterbi_vanilla(hmm.log_pi, hmm.log_A, em)
    assert np.array_equal(np.asarray(path), bf_path)
    assert np.allclose(float(score), bf_score, rtol=1e-5)


def test_vanilla(problem):
    hmm, em, *_ = problem
    _check_exact(problem, *viterbi_vanilla(hmm.log_pi, hmm.log_A, em))


def test_checkpoint(problem):
    hmm, em, *_ = problem
    _check_exact(problem, *viterbi_checkpoint(hmm.log_pi, hmm.log_A, em))


def test_sieve_mp_reference(problem):
    hmm, em, npath, nscore = problem
    path, score = ref.sieve_mp_numpy(np.asarray(hmm.log_pi),
                                     np.asarray(hmm.log_A), np.asarray(em))
    assert np.array_equal(path, npath)
    assert np.allclose(score, nscore, rtol=1e-5)


@pytest.mark.parametrize("P", [1, 4, 7])
def test_flash(problem, P):
    hmm, em, *_ = problem
    _check_exact(problem, *flash_viterbi(hmm.log_pi, hmm.log_A, em,
                                         parallelism=P))


def test_flash_lanes_vs_full(problem):
    hmm, em, *_ = problem
    p1, s1 = flash_viterbi(hmm.log_pi, hmm.log_A, em, parallelism=8, lanes=2)
    p2, s2 = flash_viterbi(hmm.log_pi, hmm.log_A, em, parallelism=8, lanes=None)
    assert np.array_equal(np.asarray(p1), np.asarray(p2))
    assert np.allclose(float(s1), float(s2))


def test_flash_bs_exact_when_beam_full(problem):
    hmm, em, *_ = problem
    K = em.shape[1]
    _check_exact(problem, *flash_bs_viterbi(hmm.log_pi, hmm.log_A, em,
                                            beam_width=K, parallelism=4,
                                            chunk=16))


def test_beam_static_exact_when_full(problem):
    hmm, em, *_ = problem
    K = em.shape[1]
    _check_exact(problem, *beam_static_viterbi(hmm.log_pi, hmm.log_A, em, B=K))
    _check_exact(problem, *beam_static_mp_viterbi(hmm.log_pi, hmm.log_A, em,
                                                  beam_width=K, parallelism=4))


def test_assoc(problem):
    hmm, em, *_ = problem
    _check_exact(problem, *viterbi_assoc(hmm.log_pi, hmm.log_A, em))


def test_beam_error_decreases(problem):
    """Paper Fig. 9: narrower beams trade accuracy; error at B=K is 0."""
    hmm, em, _, nscore = problem
    lp, lA = np.asarray(hmm.log_pi), np.asarray(hmm.log_A)
    errs = []
    for B in (4, 16, 48):
        path, _ = flash_bs_viterbi(hmm.log_pi, hmm.log_A, em, beam_width=B,
                                   parallelism=4, chunk=16)
        ps = ref.path_score_numpy(lp, lA, np.asarray(em), np.asarray(path))
        errs.append(abs(nscore - ps) / abs(nscore))
    assert errs[-1] <= 1e-5            # full beam exact
    assert errs[0] >= errs[-1]         # narrow beam no better than full


def test_api_dispatch(problem):
    hmm, em, _, nscore = problem
    for method in ("vanilla", "checkpoint", "flash", "assoc"):
        _, score = viterbi_decode(em, hmm.log_pi, hmm.log_A, method=method)
        assert np.allclose(float(score), nscore, rtol=1e-5)
    with pytest.raises(ValueError):
        viterbi_decode(em, hmm.log_pi, hmm.log_A, method="nope")


def test_left_to_right_alignment():
    """Forced alignment on a Bakis HMM: path must be monotone nondecreasing."""
    key = jax.random.key(3)
    k1, k2 = jax.random.split(key)
    hmm = left_to_right_hmm(k1, 32, 16)
    em = random_emissions(k2, 64, 32)
    path, _ = flash_viterbi(hmm.log_pi, hmm.log_A, em, parallelism=4)
    path = np.asarray(path)
    assert path[0] == 0                       # starts at the first state
    assert np.all(np.diff(path) >= 0)         # left-to-right monotone
    assert np.all(np.diff(path) <= 2)         # max_skip = 2


def test_sampled_observations_decode():
    """Decoding sampled data recovers a high-likelihood path (score of decoded
    path >= score of the true generating path)."""
    key = jax.random.key(11)
    k1, k2 = jax.random.split(key)
    hmm = erdos_renyi_hmm(k1, 24, num_obs=12, edge_prob=0.5)
    states, obs = sample_observations(k2, hmm, 48)
    em = hmm.emissions(obs)
    path, score = flash_viterbi(hmm.log_pi, hmm.log_A, em, parallelism=4)
    true_score = path_score(hmm.log_pi, hmm.log_A, em, states)
    assert float(score) >= float(true_score) - 1e-4
