"""Streaming (online) Viterbi: offline equivalence, monotone commits,
bounded lag, and the serving session/mux wrappers.

The load-bearing invariant: chunk-fed decoding with convergence-point commits
must reproduce the offline decode *bit-identically* for the exact variant, for
any chunking of the same emissions; commits must always be prefixes of the
final path."""

import numpy as np
import jax
import pytest

from repro.core import (erdos_renyi_hmm, left_to_right_hmm, random_emissions,
                        path_score, viterbi_vanilla, viterbi_decode,
                        OnlineViterbiDecoder, OnlineBeamDecoder,
                        viterbi_online, viterbi_online_beam)
from repro.serving import StreamConfig, StreamSession, StreamMux


@pytest.fixture(scope="module")
def problem():
    key = jax.random.key(42)
    k1, k2 = jax.random.split(key)
    hmm = erdos_renyi_hmm(k1, 32, edge_prob=0.3)
    em = random_emissions(k2, 97, 32)   # deliberately not a chunk multiple
    path, score = viterbi_vanilla(hmm.log_pi, hmm.log_A, em)
    return hmm, em, np.asarray(path), float(score)


# -- exact variant ----------------------------------------------------------

@pytest.mark.parametrize("chunk_size", [1, 5, 16, 64])
def test_online_exact_bit_identical(problem, chunk_size):
    hmm, em, ref_path, ref_score = problem
    path, score = viterbi_online(hmm.log_pi, hmm.log_A, em,
                                 chunk_size=chunk_size)
    assert np.array_equal(np.asarray(path), ref_path)
    assert float(score) == ref_score


def test_online_commits_are_monotone_prefixes(problem):
    hmm, em, ref_path, _ = problem
    dec = OnlineViterbiDecoder(hmm.log_pi, hmm.log_A)
    prev = 0
    for s in range(0, em.shape[0], 7):
        got = dec.feed(em[s:s + 7])
        assert got.shape[0] == dec.n_committed - prev
        prev = dec.n_committed
        # every commit so far is a prefix of the final (offline) path
        assert np.array_equal(dec.path, ref_path[:dec.n_committed])
    tail, score = dec.flush()
    assert np.array_equal(dec.path, ref_path)
    assert dec.n_committed == em.shape[0]


def test_online_converges_before_flush(problem):
    """The window must actually commit mid-stream, not just at flush."""
    hmm, em, *_ = problem
    dec = OnlineViterbiDecoder(hmm.log_pi, hmm.log_A)
    for s in range(0, em.shape[0], 16):
        dec.feed(em[s:s + 16])
    assert dec.n_committed > em.shape[0] // 2
    assert dec.stats["commits"] > 1


def test_online_bounded_lag():
    """max_lag forces commits; path stays complete and states valid."""
    k1, k2 = jax.random.split(jax.random.key(3))
    hmm = erdos_renyi_hmm(k1, 24, edge_prob=0.3)
    em = random_emissions(k2, 80, 24, scale=0.3)  # weak evidence: slow converge
    _, opt = viterbi_vanilla(hmm.log_pi, hmm.log_A, em)
    dec = OnlineViterbiDecoder(hmm.log_pi, hmm.log_A, max_lag=4)
    for s in range(0, 80, 8):
        dec.feed(em[s:s + 8])
        assert dec.lag <= 4
    dec.flush()
    p = dec.path
    assert p.shape == (80,)
    assert ((0 <= p) & (p < 24)).all()
    # forced-flush path is approximate: never better than optimal
    ps = path_score(hmm.log_pi, hmm.log_A, em, p)
    assert float(ps) <= float(opt) + 1e-4


def test_online_single_step_and_empty():
    k1, k2 = jax.random.split(jax.random.key(9))
    hmm = erdos_renyi_hmm(k1, 8, edge_prob=0.7)
    em = random_emissions(k2, 1, 8)
    dec = OnlineViterbiDecoder(hmm.log_pi, hmm.log_A)
    assert dec.feed(em[:0]).shape == (0,)
    dec.feed(em)
    tail, score = dec.flush()
    ref_path, ref_score = viterbi_vanilla(hmm.log_pi, hmm.log_A, em)
    assert np.array_equal(dec.path, np.asarray(ref_path))
    assert float(score) == float(ref_score)
    with pytest.raises(RuntimeError):
        dec.feed(em)


# -- beam variant -----------------------------------------------------------

@pytest.mark.parametrize("chunk_size", [5, 16, 64])
def test_online_beam_full_width_matches_offline(problem, chunk_size):
    hmm, em, ref_path, ref_score = problem
    K = em.shape[1]
    path, score = viterbi_online_beam(hmm.log_pi, hmm.log_A, em, beam_width=K,
                                      chunk_size=chunk_size, kchunk=8)
    assert np.array_equal(np.asarray(path), ref_path)
    assert np.allclose(float(score), ref_score, rtol=1e-5)


def test_online_beam_narrow_monotone_and_bounded(problem):
    hmm, em, _, ref_score = problem
    dec = OnlineBeamDecoder(hmm.log_pi, hmm.log_A, beam_width=8, kchunk=8)
    prefixes = []
    for s in range(0, em.shape[0], 11):
        dec.feed(em[s:s + 11])
        prefixes.append(dec.path.copy())
    dec.flush()
    final = dec.path
    assert final.shape == (em.shape[0],)
    assert all(np.array_equal(p, final[:len(p)]) for p in prefixes)
    ps = path_score(hmm.log_pi, hmm.log_A, em, final)
    assert float(ps) <= ref_score + 1e-4     # beam never beats optimal


def test_online_beam_live_state_decoupled_from_K(problem):
    hmm, em, *_ = problem
    dec = OnlineBeamDecoder(hmm.log_pi, hmm.log_A, beam_width=8, kchunk=8)
    dec.feed(em[:32])
    K = em.shape[1]
    assert dec.live_state_bytes() < 32 * K * 4   # strictly below O(W * K)


# -- api dispatch -----------------------------------------------------------

def test_api_dispatch_online(problem):
    hmm, em, ref_path, ref_score = problem
    path, score = viterbi_decode(em, hmm.log_pi, hmm.log_A, method="online",
                                 stream_chunk=32)
    assert np.array_equal(np.asarray(path), ref_path)
    path, score = viterbi_decode(em, hmm.log_pi, hmm.log_A,
                                 method="online_beam", beam_width=em.shape[1],
                                 chunk=8, stream_chunk=32)
    assert np.allclose(float(score), ref_score, rtol=1e-5)


# -- serving layer ----------------------------------------------------------

def test_stream_session_ragged_feeds(problem):
    hmm, em, ref_path, ref_score = problem
    sess = StreamSession(hmm.log_pi, hmm.log_A, StreamConfig(), block=16)
    i = 0
    for n in (3, 20, 1, 40, 33):
        sess.feed(np.asarray(em[i:i + n]))
        i += n
    path, score = sess.finish()
    assert np.array_equal(path, ref_path)
    assert float(score) == ref_score


def test_stream_mux_concurrent_sessions(problem):
    hmm, em, ref_path, _ = problem
    mux = StreamMux(hmm.log_pi, hmm.log_A, blocks=(16, 64))
    a, b = mux.open(block=16), mux.open(block=50)
    assert mux.sessions_by_bucket()[16] == [a]
    assert mux.sessions_by_bucket()[64] == [b]
    for s in range(0, em.shape[0], 25):
        chunk = np.asarray(em[s:s + 25])
        out = mux.feed(a, chunk)
        assert out["n_committed"] >= out["committed"].shape[0]
        mux.feed(b, chunk)
    pa, _ = mux.finish(a)
    pb, _ = mux.finish(b)
    assert np.array_equal(pa, ref_path)
    assert np.array_equal(pb, ref_path)
    assert mux.stats["finished"] == 2


# -- session / mux lifecycle ------------------------------------------------

def _no_converge_hmm():
    """Two disconnected, symmetric chains: hypotheses never merge, so no
    convergence commit can ever fire — the window only grows."""
    log_pi = np.zeros((2,), np.float32)
    log_A = np.array([[0.0, -100.0], [-100.0, 0.0]], np.float32)
    return log_pi, log_A


def test_stream_finish_unfed_session(problem):
    hmm, _, _, _ = problem
    mux = StreamMux(hmm.log_pi, hmm.log_A, blocks=(16,))
    sid = mux.open(block=16)
    path, score = mux.finish(sid)
    assert path.shape == (0,)
    assert np.isnan(score)
    assert mux.stats["finished"] == 1


def test_stream_session_finish_is_idempotent(problem):
    hmm, em, ref_path, ref_score = problem
    sess = StreamSession(hmm.log_pi, hmm.log_A, StreamConfig(), block=16)
    sess.feed(np.asarray(em[:40]))
    p1, s1 = sess.finish()
    p2, s2 = sess.finish()
    assert np.array_equal(p1, p2) and s1 == s2
    vp, vs = viterbi_vanilla(hmm.log_pi, hmm.log_A, em[:40])
    assert np.array_equal(p1, np.asarray(vp))
    assert float(s1) == float(vs)


def test_stream_mux_double_finish_raises(problem):
    hmm, em, _, _ = problem
    mux = StreamMux(hmm.log_pi, hmm.log_A, blocks=(16,))
    sid = mux.open(block=16)
    mux.feed(sid, np.asarray(em[:20]))
    mux.finish(sid)
    with pytest.raises(KeyError, match="unknown or already-finished"):
        mux.finish(sid)
    with pytest.raises(KeyError, match="unknown or already-finished"):
        mux.feed(sid, np.asarray(em[:4]))


def test_stream_session_feed_after_finish_raises(problem):
    """Regression: a sub-block feed after finish() used to buffer silently
    (the decoder only sees whole blocks, so nothing raised) — the frames were
    dropped on the floor."""
    hmm, em, _, _ = problem
    sess = StreamSession(hmm.log_pi, hmm.log_A, StreamConfig(), block=16)
    sess.feed(np.asarray(em[:20]))
    sess.finish()
    with pytest.raises(RuntimeError, match="already finished"):
        sess.feed(np.asarray(em[20:23]))   # smaller than one block


def test_stream_live_state_bytes_counts_buffered_frames():
    """Regression: live_state_bytes() ignored the feed buffer, sitting flat
    while sub-block feeds accumulated live frames."""
    log_pi, log_A = _no_converge_hmm()
    sess = StreamSession(log_pi, log_A, StreamConfig(), block=64)
    sizes = [sess.live_state_bytes()]
    for _ in range(3):
        sess.feed(np.zeros((8, 2), np.float32))   # sub-block: buffered only
        sizes.append(sess.live_state_bytes())
    assert all(b > a for a, b in zip(sizes, sizes[1:]))


def test_stream_live_state_bytes_monotone_without_commits():
    """With no convergence points and no max_lag, feeding never shrinks the
    reported live state — across both buffered and whole-block advances."""
    log_pi, log_A = _no_converge_hmm()
    sess = StreamSession(log_pi, log_A, StreamConfig(), block=16)
    sizes = [sess.live_state_bytes()]
    for _ in range(10):
        out = sess.feed(np.zeros((7, 2), np.float32))
        assert out.shape == (0,)                  # nothing ever commits
        sizes.append(sess.live_state_bytes())
    assert all(b >= a for a, b in zip(sizes, sizes[1:]))
    assert sizes[-1] > sizes[0]


def test_stream_left_to_right_alignment_online():
    """Streaming decode of a Bakis model keeps the alignment constraints."""
    k1, k2 = jax.random.split(jax.random.key(7))
    hmm = left_to_right_hmm(k1, 32, 16)
    em = random_emissions(k2, 64, 32)
    path, _ = viterbi_online(hmm.log_pi, hmm.log_A, em, chunk_size=10)
    path = np.asarray(path)
    assert path[0] == 0
    assert np.all(np.diff(path) >= 0)
    assert np.all(np.diff(path) <= 2)
