"""Per-arch smoke tests: REDUCED same-family configs, one forward/train step on
CPU, asserting output shapes and finiteness (assignment deliverable f)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
import zlib

from repro.configs import ARCH_IDS, get_arch
from repro.configs.base import smoke_batch, SHAPES
from repro.models import build_model


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_smoke_train_step(arch_id):
    mod = get_arch(arch_id)
    cfg = mod.SMOKE
    model = build_model(cfg)
    key = jax.random.key(zlib.crc32(arch_id.encode()) % 2**31)
    k1, k2 = jax.random.split(key)
    params = model.init(k1)

    kw = {}
    if cfg.num_image_tokens:
        kw["num_image_tokens"] = cfg.num_image_tokens
    elif not cfg.embed_inputs:
        kw["embeds"] = True
    batch = smoke_batch(cfg, k2, batch=2, seq=16, **kw)

    loss, grads = jax.value_and_grad(model.loss)(params, batch)
    assert jnp.isfinite(loss), f"{arch_id}: non-finite loss"
    gn = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree_util.tree_leaves(grads))
    assert jnp.isfinite(gn), f"{arch_id}: non-finite grads"


@pytest.mark.parametrize("arch_id", [a for a in ARCH_IDS
                                     if a != "hubert_xlarge"])
def test_smoke_prefill_decode_consistency(arch_id):
    """Greedy decode after prefill matches teacher-forced full forward."""
    mod = get_arch(arch_id)
    cfg = mod.SMOKE
    model = build_model(cfg)
    key = jax.random.key(1 + zlib.crc32(arch_id.encode()) % 2**31)
    k1, k2 = jax.random.split(key)
    params = model.init(k1)
    kw = {"num_image_tokens": cfg.num_image_tokens} if cfg.num_image_tokens else {}
    batch = smoke_batch(cfg, k2, batch=2, seq=16, **kw)

    logits, cache = model.prefill(params, batch, max_len=32)
    assert logits.shape[1] == 1
    assert bool(jnp.all(jnp.isfinite(logits)))
    tok = jnp.argmax(logits[:, -1], -1)[:, None]
    for _ in range(3):
        logits, cache = model.decode_step(params, tok, cache)
        assert bool(jnp.all(jnp.isfinite(logits)))
        tok = jnp.argmax(logits[:, -1], -1)[:, None]


def test_encoder_prefill_emissions():
    mod = get_arch("hubert_xlarge")
    cfg = mod.SMOKE
    model = build_model(cfg)
    key = jax.random.key(9)
    params = model.init(key)
    batch = {"embeds": jax.random.normal(key, (2, 16, cfg.d_model), cfg.dtype)}
    logits, cache = model.prefill(params, batch)
    assert cache is None
    assert logits.shape == (2, 16, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    with pytest.raises(ValueError):
        model.decode_step(params, jnp.zeros((2, 1), jnp.int32), {})


def test_input_specs_cell_count():
    """All 40 (arch x shape) cells are accounted for: runnable or documented."""
    total, runnable, skipped = 0, 0, 0
    for arch_id in ARCH_IDS:
        mod = get_arch(arch_id)
        for shape in SHAPES:
            total += 1
            spec = mod.input_specs(shape)
            if spec is None:
                assert shape in mod.SKIPS, f"{arch_id}/{shape} skip undocumented"
                skipped += 1
            else:
                runnable += 1
                kind, S, B = SHAPES[shape]
                assert spec.kind == kind
                args = jax.tree_util.tree_leaves(spec.args)
                assert all(hasattr(a, "shape") for a in args)
    assert total == 40
    assert runnable == 32 and skipped == 8


def test_decode_matches_full_forward_tinyllama():
    """Stronger consistency: stepwise decode logits == teacher-forced logits."""
    cfg = get_arch("tinyllama_1_1b").SMOKE
    model = build_model(cfg)
    key = jax.random.key(4)
    k1, k2 = jax.random.split(key)
    params = model.init(k1)
    toks = jax.random.randint(k2, (1, 8), 0, cfg.vocab)

    # teacher-forced: prefill on the full sequence gives last-position logits
    full_logits, _ = model.prefill(params, {"tokens": toks}, max_len=16)

    # stepwise: prefill on the first 7, then decode token 8
    pre_logits, cache = model.prefill(params, {"tokens": toks[:, :7]},
                                      max_len=16)
    step_logits, _ = model.decode_step(params, toks[:, 7:8], cache)
    np.testing.assert_allclose(np.asarray(step_logits[0, 0]),
                               np.asarray(full_logits[0, 0]),
                               atol=2e-2, rtol=2e-2)  # bf16 path tolerance
