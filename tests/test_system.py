"""End-to-end behaviour tests: the paper's serving pipeline + training loop."""

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (left_to_right_hmm, erdos_renyi_hmm, random_emissions,
                        viterbi_vanilla, relative_error)
from repro.serving.alignment import AlignmentConfig, make_alignment_head
from repro.serving.scheduler import BatchScheduler


def test_alignment_serving_end_to_end():
    """Encoder-emissions -> FLASH-BS alignment through the batch scheduler,
    validated against exact Viterbi (paper Fig. 9 style)."""
    key = jax.random.key(0)
    k1, k2 = jax.random.split(key)
    hmm = left_to_right_hmm(k1, 64, 16)
    head = make_alignment_head(hmm.log_pi, hmm.log_A,
                               AlignmentConfig(method="flash_bs",
                                               beam_width=48, parallelism=4))
    sched = BatchScheduler(head, max_batch=4, buckets=(64,))
    rng = np.random.default_rng(0)
    # exact-bucket lengths: pad frames extend the DP and perturb the decoded
    # prefix (documented scheduler approximation, tested separately below)
    reqs = [sched.submit(rng.standard_normal((64, 64)).astype(np.float32))
            for _ in range(6)]
    done = sched.drain()
    assert len(done) == 6
    errs = []
    for r in done:
        em = jnp.asarray(r.payload)
        _, opt = viterbi_vanilla(hmm.log_pi, hmm.log_A, em)
        errs.append(float(relative_error(opt, r.result[1])))
    assert np.mean(errs) < 0.05  # B=48/64 beam on random emissions


def test_training_loop_loss_decreases(tmp_path):
    """The end-to-end driver trains a tiny model and the loss goes down."""
    from repro.launch.train import main
    losses = main(["--arch", "tinyllama-1.1b", "--smoke", "--steps", "30",
                   "--batch", "4", "--seq", "64", "--lr", "1e-2",
                   "--ckpt-dir", str(tmp_path), "--ckpt-every", "10"])
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0] - 0.3


def test_training_resume_bitexact(tmp_path):
    """Checkpoint/restart: resuming reproduces the uninterrupted run."""
    from repro.launch.train import main
    args = ["--batch", "2", "--seq", "32", "--lr", "1e-3", "--horizon", "10",
            "--ckpt-every", "5", "--smoke", "--arch", "tinyllama-1.1b"]
    full = main(["--steps", "10", "--ckpt-dir", str(tmp_path / "a")] + args)
    part = main(["--steps", "5", "--ckpt-dir", str(tmp_path / "b")] + args)
    resumed = main(["--steps", "10", "--resume",
                    "--ckpt-dir", str(tmp_path / "b")] + args)
    assert np.isfinite(full).all() and np.isfinite(resumed).all()
    np.testing.assert_allclose(full[5:], resumed, rtol=2e-4, atol=2e-5)


def test_scheduler_padding_is_bounded_approximation():
    """Bucket padding perturbs alignment scores only mildly (tail effect)."""
    key = jax.random.key(2)
    k1, k2 = jax.random.split(key)
    hmm = left_to_right_hmm(k1, 32, 8)
    rng = np.random.default_rng(1)
    em = rng.standard_normal((24, 32)).astype(np.float32)
    em_pad = np.zeros((32, 32), np.float32)
    em_pad[:24] = em
    _, exact = viterbi_vanilla(hmm.log_pi, hmm.log_A, jnp.asarray(em))
    from repro.core import flash_bs_viterbi, path_score
    p_pad, _ = flash_bs_viterbi(hmm.log_pi, hmm.log_A, jnp.asarray(em_pad),
                                beam_width=32, parallelism=4)
    ll = path_score(hmm.log_pi, hmm.log_A, jnp.asarray(em), p_pad[:24])
    assert float(relative_error(exact, ll)) < 0.25
