"""End-to-end behaviour tests: the paper's serving pipeline + training loop."""

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (left_to_right_hmm, erdos_renyi_hmm,
                        viterbi_vanilla, relative_error)
from repro.serving.alignment import AlignmentConfig, make_alignment_head
from repro.serving.scheduler import BatchScheduler


def test_alignment_serving_end_to_end():
    """Encoder-emissions -> FLASH-BS alignment through the batch scheduler,
    validated against exact Viterbi (paper Fig. 9 style).  Ragged lengths are
    masked by the batched decoder, so the only error source is the beam."""
    key = jax.random.key(0)
    k1, k2 = jax.random.split(key)
    hmm = left_to_right_hmm(k1, 64, 16)
    head = make_alignment_head(hmm.log_pi, hmm.log_A,
                               AlignmentConfig(method="flash_bs",
                                               beam_width=48, parallelism=4))
    sched = BatchScheduler(head, max_batch=4, buckets=(64,))
    rng = np.random.default_rng(0)
    lens = [64, 40, 64, 25, 64, 52]
    reqs = [sched.submit(rng.standard_normal((t, 64)).astype(np.float32))
            for t in lens]
    done = sched.drain()
    assert len(done) == 6
    errs = []
    for r in done:
        em = jnp.asarray(r.payload)
        assert r.result[0].shape == (len(r.payload),)
        _, opt = viterbi_vanilla(hmm.log_pi, hmm.log_A, em)
        errs.append(float(relative_error(opt, r.result[1])))
    assert np.mean(errs) < 0.05  # B=48/64 beam on random emissions


def test_training_loop_loss_decreases(tmp_path):
    """The end-to-end driver trains a tiny model and the loss goes down."""
    from repro.launch.train import main
    losses = main(["--arch", "tinyllama-1.1b", "--smoke", "--steps", "30",
                   "--batch", "4", "--seq", "64", "--lr", "1e-2",
                   "--ckpt-dir", str(tmp_path), "--ckpt-every", "10"])
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0] - 0.3


def test_training_resume_bitexact(tmp_path):
    """Checkpoint/restart: resuming reproduces the uninterrupted run."""
    from repro.launch.train import main
    args = ["--batch", "2", "--seq", "32", "--lr", "1e-3", "--horizon", "10",
            "--ckpt-every", "5", "--smoke", "--arch", "tinyllama-1.1b"]
    full = main(["--steps", "10", "--ckpt-dir", str(tmp_path / "a")] + args)
    part = main(["--steps", "5", "--ckpt-dir", str(tmp_path / "b")] + args)
    resumed = main(["--steps", "10", "--resume",
                    "--ckpt-dir", str(tmp_path / "b")] + args)
    assert np.isfinite(full).all() and np.isfinite(resumed).all()
    np.testing.assert_allclose(full[5:], resumed, rtol=2e-4, atol=2e-5)


def test_scheduler_bit_identical_to_unbatched():
    """Regression for the padded-batch corruption bug: with an exact method,
    every scheduled request's path AND score must be bit-identical to an
    unbatched decode of its unpadded payload — bucket pad frames run as
    tropical-identity steps, never as real DP transitions."""
    key = jax.random.key(2)
    k1, _ = jax.random.split(key)
    hmm = erdos_renyi_hmm(k1, 32, edge_prob=0.4)
    head = make_alignment_head(hmm.log_pi, hmm.log_A,
                               AlignmentConfig(method="fused"))
    sched = BatchScheduler(head, max_batch=4, buckets=(48,))
    rng = np.random.default_rng(1)
    lens = [48, 20, 33, 1, 48]
    reqs = [sched.submit(rng.standard_normal((t, 32)).astype(np.float32))
            for t in lens]
    done = sched.drain()
    assert len(done) == len(lens)
    for r in done:
        em = jnp.asarray(r.payload)
        opt_path, opt_score = viterbi_vanilla(hmm.log_pi, hmm.log_A, em)
        assert np.array_equal(r.result[0], np.asarray(opt_path))
        assert np.isclose(r.result[1], float(opt_score), rtol=1e-6, atol=0)
