"""Tests for the flashlint gate (`repro.analysis`): rule fixtures, disable
grammar, self-cleanliness of `src/`, the CI exit-code contract, the
trace-time contract checker's pinned tolerances, and the retrace guard."""

from __future__ import annotations

import pathlib
import subprocess
import sys
import textwrap

import numpy as np
import jax.numpy as jnp
import pytest

from repro.analysis import (RULES, check_contracts, lint_paths, lint_source,
                            MEMORY_TOLERANCE, RetraceError, RetraceGuard)
from repro.analysis.contracts import (check_memory_contracts,
                                      check_shape_contracts,
                                      check_streaming_contracts)
from repro.analysis.retrace import check_retrace, supported
from repro.core import ViterbiDecoder
from repro.core.spec import (FlashBSSpec, FlashSpec, FusedSpec, SPEC_BY_METHOD,
                             VanillaSpec)

SRC = pathlib.Path(__file__).resolve().parent.parent / "src" / "repro"

HOT = "src/repro/core/somefile.py"          # FL002 applies
COLD = "src/repro/serving/somefile.py"      # FL002 does not


def codes(src: str, path: str) -> list[str]:
    return [v.code for v in lint_source(textwrap.dedent(src), path)]


# ---------------------------------------------------------------------------
# Rule fixtures: positive + negative per rule
# ---------------------------------------------------------------------------

def test_fl001_raw_mesh_api_flagged_outside_jaxcompat():
    assert codes("import jax\nm = jax.make_mesh((2,), ('x',))\n",
                 COLD) == ["FL001"]
    assert codes("from jax.experimental.shard_map import shard_map\n",
                 COLD) == ["FL001"]
    assert codes("import jax\nam = jax.sharding.AbstractMesh((2,), ('x',))\n",
                 COLD) == ["FL001"]


def test_fl001_allowed_inside_jaxcompat_and_via_shim():
    src = "import jax\nm = jax.make_mesh((2,), ('x',))\n"
    assert codes(src, "src/repro/runtime/jaxcompat.py") == []
    assert codes("from repro.runtime.jaxcompat import shard_map\n", COLD) == []


def test_fl002_host_syncs_flagged_in_hot_paths_only():
    fixtures = [
        "x = delta.item()\n",
        "import numpy as np\nx = np.asarray(delta)\n",
        "import jax\nx = jax.device_get(delta)\n",
        "import jax.numpy as jnp\nx = float(jnp.max(delta))\n",
        "q = int(self._delta[0])\n",
    ]
    for src in fixtures:
        assert codes(src, HOT) == ["FL002"], src
        assert codes(src, COLD) == [], src


def test_fl002_static_metadata_is_exempt():
    assert codes("import jax.numpy as jnp\n"
                 "n = int(jnp.zeros((3,)).shape[0])\n", HOT) == []
    assert codes("k = int(self.log_A.shape[0])\n", HOT) == []


def test_fl003_sys_path_manipulation():
    assert codes("import sys\nsys.path.insert(0, 'src')\n", COLD) == ["FL003"]
    assert codes("import sys\nprint(sys.argv)\n", COLD) == []


def test_fl004_string_dispatch_outside_shim_and_tests():
    src = "p, s = viterbi_decode(pi, A, em, method='flash')\n"
    assert codes(src, COLD) == ["FL004"]
    assert codes(src, "src/repro/core/api.py") == []
    assert codes(src, "tests/test_something.py") == []


def test_fl005_malformed_disables():
    assert codes("x = 1  # flashlint: disable=FL999(made up)\n",
                 COLD) == ["FL005"]
    # an empty reason is FL005 AND suppresses nothing
    got = codes("x = delta.item()  # flashlint: disable=FL002()\n", HOT)
    assert sorted(got) == ["FL002", "FL005"]


def test_fl006_raw_pallas_flagged_outside_kernels():
    assert codes("import jax.experimental.pallas as pl\n", COLD) == ["FL006"]
    assert codes("from jax.experimental import pallas as pl\n",
                 COLD) == ["FL006"]
    assert codes("from jax.experimental.pallas import pallas_call\n",
                 COLD) == ["FL006"]
    assert codes("out = pl.pallas_call(body, grid=(4,))(x)\n",
                 COLD) == ["FL006"]
    assert codes("spec = pltpu.BlockSpec((8, 128), lambda i: (i, 0))\n",
                 COLD) == ["FL006"]


def test_fl006_allowed_in_kernels_tests_and_with_reason():
    src = "import jax.experimental.pallas as pl\n"
    assert codes(src, "src/repro/kernels/viterbi_dp.py") == []
    assert codes(src, "tests/test_kernels.py") == []
    assert codes("import jax.experimental.pallas as pl"
                 "  # flashlint: disable=FL006(prototype bench)\n",
                 COLD) == []
    # a non-pallas root spelling the same attribute is not a violation
    assert codes("spec = mylib.BlockSpec((8, 128))\n", COLD) == []


def test_fl007_manual_neg_inf_masking_flagged():
    fixtures = [
        "import jax.numpy as jnp\ny = jnp.where(mask, x, NEG_INF)\n",
        "import jax.numpy as jnp\ny = jnp.where(keep, d, d + 4.0 * NEG_INF)\n",
        "import jax.numpy as jnp\ny = jnp.where(mask, x, -jnp.inf)\n",
        "import jax.numpy as jnp\ny = jnp.where(mask, x, float('-inf'))\n",
        "import jax.numpy as jnp\ny = jnp.where(mask, x, -1.0e9)\n",
        "import numpy as np\ny = np.where(mask, x, -np.inf)\n",
    ]
    for src in fixtures:
        assert codes(src, COLD) == ["FL007"], src


def test_fl007_exempt_in_constraints_kernels_and_tests():
    src = "import jax.numpy as jnp\ny = jnp.where(mask, x, NEG_INF)\n"
    assert codes(src, "src/repro/core/constraints.py") == []
    assert codes(src, "src/repro/kernels/ops.py") == []
    assert codes(src, "tests/test_constraints.py") == []
    assert codes(src + "  # ok", COLD) == ["FL007"]   # COLD is not exempt
    assert codes("import jax.numpy as jnp\n"
                 "# flashlint: disable=FL007(sentinel padding seam)\n"
                 "y = jnp.where(mask, x, NEG_INF)\n", COLD) == []


def test_fl007_benign_wheres_not_flagged():
    # no neg-inf constant anywhere in the arguments: not a mask
    assert codes("import jax.numpy as jnp\n"
                 "y = jnp.where(mask, x, 0.0)\n", COLD) == []
    assert codes("import jax.numpy as jnp\n"
                 "y = jnp.where(is_pad, delta, new)\n", COLD) == []
    # small negative literals are scores, not sentinels
    assert codes("import jax.numpy as jnp\n"
                 "y = jnp.where(mask, x, -30.0)\n", COLD) == []


# ---------------------------------------------------------------------------
# Disable grammar
# ---------------------------------------------------------------------------

def test_disable_same_line_and_previous_line():
    assert codes("x = delta.item()  # flashlint: disable=FL002(commit point)\n",
                 HOT) == []
    assert codes("# flashlint: disable=FL002(commit point)\n"
                 "x = delta.item()\n", HOT) == []


def test_disable_requires_reason_and_right_code():
    # a reasoned FL002 disable does not silence an FL003 on the same line
    assert codes("import sys\n"
                 "sys.path.insert(0, 'x')  # flashlint: disable=FL002(nope)\n",
                 HOT) == ["FL003"]


def test_disable_file_silences_whole_module():
    src = ("# flashlint: disable-file=FL002(host-side oracle)\n"
           "a = delta.item()\n"
           "b = other.item()\n")
    assert codes(src, HOT) == []


def test_grammar_in_docstrings_is_not_a_directive():
    src = '"""Use ``# flashlint: disable=FL002(reason)`` comments."""\n'
    assert codes(src, HOT) == []


# ---------------------------------------------------------------------------
# Self-clean + exit-code contract
# ---------------------------------------------------------------------------

def test_src_tree_is_flashlint_clean():
    violations, n_files = lint_paths([SRC])
    assert n_files > 50
    assert violations == [], "\n".join(str(v) for v in violations)


def test_cli_exit_codes(tmp_path):
    # seeded violation in a hot-path-shaped tree -> non-zero exit
    bad = tmp_path / "core" / "bad.py"
    bad.parent.mkdir()
    bad.write_text("import numpy as np\nx = np.asarray(delta)\n")
    env_src = str(pathlib.Path(__file__).resolve().parent.parent / "src")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--lint-only",
         str(tmp_path)],
        capture_output=True, text=True,
        env={"PYTHONPATH": env_src, "PATH": "/usr/bin:/bin",
             "JAX_PLATFORMS": "cpu"})
    assert proc.returncode == 1, proc.stdout + proc.stderr
    assert "FL002" in proc.stdout
    bad.write_text("x = 1\n")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--lint-only",
         str(tmp_path)],
        capture_output=True, text=True,
        env={"PYTHONPATH": env_src, "PATH": "/usr/bin:/bin",
             "JAX_PLATFORMS": "cpu"})
    assert proc.returncode == 0, proc.stdout + proc.stderr


# ---------------------------------------------------------------------------
# Contract checker
# ---------------------------------------------------------------------------

def test_every_registered_method_has_contract_coverage():
    report = check_contracts(quick=True)
    assert report.ok, "\n".join(report.failures)


def test_shape_contracts_small_grid():
    report = check_shape_contracts(grid=((8, 16),), batch_grid=((8, 16, 3),))
    assert report.ok, "\n".join(report.failures)
    assert len(report.checks) > 0


def test_memory_tolerance_pinned_for_key_specs():
    specs = (VanillaSpec(), FlashSpec(), FusedSpec(), FlashBSSpec())
    report = check_memory_contracts(specs=specs, grid=((24, 64),))
    assert report.ok, "\n".join(report.failures)
    for spec in specs:
        if (spec.method, 24, 64) in report.memory_ratios:
            ratio = report.memory_ratios[(spec.method, 24, 64)]
            assert ratio <= MEMORY_TOLERANCE[spec.method]


def test_memory_tolerance_table_covers_every_jittable_method():
    for method, cls in SPEC_BY_METHOD.items():
        if cls.jittable:
            assert method in MEMORY_TOLERANCE


def test_streaming_live_state_bounded_by_planner_model():
    report = check_streaming_contracts(K=12, T=32)
    assert report.ok, "\n".join(report.failures)


# ---------------------------------------------------------------------------
# Retrace guard
# ---------------------------------------------------------------------------

@pytest.mark.skipif(not supported(), reason="jit._cache_size unavailable")
def test_no_retrace_battery_passes():
    passed = check_retrace(specs=(VanillaSpec(),), K=8, T=12)
    assert any("equal-spec" in p for p in passed)
    assert any("positive control" in p for p in passed)


@pytest.mark.skipif(not supported(), reason="jit._cache_size unavailable")
def test_guard_catches_a_real_retrace():
    spec = VanillaSpec()
    rng = np.random.default_rng(7)
    dec = ViterbiDecoder(spec, jnp.asarray(rng.standard_normal(8), jnp.float32),
                         jnp.asarray(rng.standard_normal((8, 8)), jnp.float32))
    dec.decode(jnp.asarray(rng.standard_normal((10, 8)), jnp.float32))
    with pytest.raises(RetraceError):
        with RetraceGuard([spec]):
            # a brand-new T is a new shape bucket: must be flagged when the
            # guard allows zero compiles
            dec.decode(jnp.asarray(rng.standard_normal((11, 8)), jnp.float32))


def test_equal_specs_share_one_compilation():
    if not supported():
        pytest.skip("jit._cache_size unavailable")
    from repro.core.decoder import _jit_decode
    rng = np.random.default_rng(3)
    pi = jnp.asarray(rng.standard_normal(9), jnp.float32)
    A = jnp.asarray(rng.standard_normal((9, 9)), jnp.float32)
    em = jnp.asarray(rng.standard_normal((14, 9)), jnp.float32)
    spec = FlashSpec(parallelism=2)
    ViterbiDecoder(spec, pi, A).decode(em)
    before = _jit_decode(FlashSpec(parallelism=2))._cache_size()
    ViterbiDecoder(FlashSpec(parallelism=2), pi, A).decode(em)
    assert _jit_decode(FlashSpec(parallelism=2))._cache_size() == before
