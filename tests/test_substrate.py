"""Substrate tests: optimizer, compression, checkpointing, fault tolerance,
elastic planning, data pipeline determinism, serving scheduler."""


import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.optim import (AdamWConfig, init_state, update, schedule,
                         zero1_specs, dequantize, ef_accumulate)
from repro.checkpointing.manager import CheckpointManager
from repro.checkpointing.elastic import plan_rescale, abstract_target_mesh
from repro.runtime.fault import (HeartbeatMonitor, StragglerDetector,
                                 SupervisedLoop)
from repro.data.pipeline import SyntheticTokenPipeline, TokenPipelineConfig


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_reduces_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1,
                      total_steps=100)
    params = {"w": jnp.array([3.0, -2.0])}
    state = init_state(params)
    for _ in range(50):
        grads = {"w": 2 * params["w"]}       # d/dw (w^2)
        params, state, m = update(cfg, grads, state, params)
    assert float(jnp.sum(jnp.square(params["w"]))) < 0.2


def test_schedule_warmup_and_decay():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_ratio=0.1)
    assert float(schedule(cfg, 5)) == pytest.approx(0.5)
    assert float(schedule(cfg, 10)) == pytest.approx(1.0)
    assert float(schedule(cfg, 100)) == pytest.approx(0.1, rel=1e-3)


def test_zero1_specs_shard_largest_free_axis():
    specs = {"w": P(None, "model"), "b": P()}
    shapes = {"w": jax.ShapeDtypeStruct((64, 128), jnp.float32),
              "b": jax.ShapeDtypeStruct((7,), jnp.float32)}
    out = zero1_specs(specs, shapes, ("data",), data_size=16)
    assert out["w"] == P("data", "model")
    assert tuple(out["b"]) in ((), (None,))   # 7 not divisible: replicated


def test_compression_error_feedback_converges():
    """Accumulating N identical grads through int8+EF loses < 1% of the sum."""
    g = jax.random.normal(jax.random.key(0), (256,)) * 1e-3
    q = jnp.zeros((256,), jnp.int8)
    scale = jnp.zeros(())
    res = jnp.zeros((256,))
    for _ in range(16):
        q, scale, res = ef_accumulate(q, scale, res, g)
    acc = dequantize(q, scale) + res
    np.testing.assert_allclose(np.asarray(acc), np.asarray(16 * g), rtol=1e-2,
                               atol=1e-5)


# ---------------------------------------------------------------------------
# checkpointing + fault tolerance
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    state = {"params": {"w": jnp.arange(6.0).reshape(2, 3)},
             "step": jnp.asarray(3)}
    for s in (10, 20, 30):
        mgr.save(s, state, blocking=True)
    assert mgr.all_steps() == [20, 30]        # keep=2 GC'd step 10
    restored = mgr.restore(30, state)
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(state["params"]["w"]))


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"w": jnp.zeros((2, 2))}, blocking=True)
    with pytest.raises(ValueError):
        mgr.restore(1, {"w": jnp.zeros((3, 3))})


def test_supervised_loop_restarts_from_checkpoint(tmp_path):
    """Inject a failure mid-run; the loop restores and replays identically."""
    mgr = CheckpointManager(str(tmp_path), keep=5)
    calls = {"n": 0}

    def step_fn(state, batch):
        return {"x": state["x"] + batch}, {"loss": state["x"]}

    def chaos(step):
        calls["n"] += 1
        if step == 7 and not calls.get("failed"):   # fail once at step 7
            calls["failed"] = True
            raise RuntimeError("injected node failure")

    loop = SupervisedLoop(step_fn, {"x": jnp.asarray(0.0)}, mgr,
                          batch_fn=lambda s: jnp.asarray(1.0),
                          ckpt_every=5, chaos=chaos)
    state, log = loop.run(0, 10)
    assert loop.restarts == 1
    assert float(state["x"]) == 10.0          # exact replay after restore


def test_heartbeat_and_straggler():
    clock = {"t": 0.0}
    hb = HeartbeatMonitor(3, timeout_s=5.0, clock=lambda: clock["t"])
    clock["t"] = 3.0
    hb.beat(0), hb.beat(1)
    clock["t"] = 7.0
    assert hb.dead_workers() == [2]

    sd = StragglerDetector(num_workers=4, factor=3.0)
    for w in range(4):
        for _ in range(4):
            sd.record(w, 1.0)
    sd.record(2, 9.0)
    assert sd.stragglers() == [2]


def test_elastic_plan_rescale():
    # abstract target mesh: plan_rescale only reads shapes (1-device test
    # host); constructed through the jaxcompat shim — AbstractMesh's
    # signature differs between jax 0.4.x and current jax
    mesh_ok = abstract_target_mesh((2, 2), ("data", "model"))
    shapes = {"w": jax.ShapeDtypeStruct((64, 128), jnp.float32)}
    specs = {"w": P("data", "model")}
    assert plan_rescale(shapes, specs, mesh_ok) == []
    shapes_bad = {"w": jax.ShapeDtypeStruct((63, 128), jnp.float32)}
    assert len(plan_rescale(shapes_bad, specs, mesh_ok)) == 1


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_pipeline_deterministic_and_resumable():
    cfg = TokenPipelineConfig(vocab=100, seq_len=16, global_batch=4, seed=3)
    p1, p2 = SyntheticTokenPipeline(cfg), SyntheticTokenPipeline(cfg)
    for step in (0, 5, 17):
        b1, b2 = p1.batch(step), p2.batch(step)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(p1.batch(1)["tokens"], p1.batch(2)["tokens"])


def test_pipeline_labels_are_shifted_tokens():
    cfg = TokenPipelineConfig(vocab=50, seq_len=8, global_batch=2, seed=0)
    b = SyntheticTokenPipeline(cfg).batch(0)
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])
    assert (b["mask"][:, -1] == 0).all()


# ---------------------------------------------------------------------------
# serving scheduler
# ---------------------------------------------------------------------------

def test_batch_scheduler_buckets_and_results():
    from repro.serving.scheduler import BatchScheduler

    def fake_decode(batch, lengths):          # (B, T, K), (B,) -> paths, scores
        B, T, K = batch.shape
        assert lengths.shape == (B,)
        return np.zeros((B, T), np.int32), np.arange(B, dtype=np.float32)

    sched = BatchScheduler(fake_decode, max_batch=3, buckets=(64, 128))
    reqs = [sched.submit(np.zeros((50, 8), np.float32)) for _ in range(4)]
    reqs += [sched.submit(np.zeros((100, 8), np.float32))]
    done = sched.drain()
    assert len(done) == 5 and all(r.done for r in reqs)
    assert all(r.result[0].shape[0] == len(r.payload) for r in reqs)
    assert sched.stats["batches"] >= 2        # two buckets at least
