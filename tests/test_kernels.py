"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs ref.py oracles."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize("I,K,J", [(8, 16, 128), (64, 128, 256), (37, 100, 200),
                                   (1, 512, 512), (128, 64, 384)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_tropical_matmul(I, K, J, dtype):
    k1, k2 = jax.random.split(jax.random.key(I * 1000 + J))
    a = jax.random.normal(k1, (I, K), dtype=jnp.float32).astype(dtype)
    b = jax.random.normal(k2, (K, J), dtype=jnp.float32).astype(dtype)
    v, g = ops.tropical_matmul(a, b)
    vr, gr = ref.tropical_matmul_ref(a, b)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(v, np.float32),
                               np.asarray(vr, np.float32), atol=tol, rtol=tol)
    assert np.array_equal(np.asarray(g), np.asarray(gr))


@pytest.mark.parametrize("T,K", [(16, 128), (33, 128), (24, 256), (7, 384)])
def test_viterbi_forward_kernel(T, K):
    k1, k2, k3 = jax.random.split(jax.random.key(T * 31 + K), 3)
    A = jax.random.normal(k1, (K, K))
    em = jax.random.normal(k2, (T, K))
    d0 = jax.random.normal(k3, (K,))
    psi, dT = ops.viterbi_forward(A, em, d0)
    psir, dTr = ref.viterbi_forward_ref(A, em, d0)
    assert np.array_equal(np.asarray(psi), np.asarray(psir))
    np.testing.assert_allclose(np.asarray(dT), np.asarray(dTr),
                               atol=1e-4, rtol=1e-5)


def test_viterbi_forward_large_k_fallback():
    """K not 128-aligned falls back to the XLA path, same results."""
    k1, k2, k3 = jax.random.split(jax.random.key(0), 3)
    K, T = 200, 12
    A = jax.random.normal(k1, (K, K))
    em = jax.random.normal(k2, (T, K))
    d0 = jax.random.normal(k3, (K,))
    psi, dT = ops.viterbi_forward(A, em, d0)
    psir, dTr = ref.viterbi_forward_ref(A, em, d0)
    assert np.array_equal(np.asarray(psi), np.asarray(psir))


def test_viterbi_decode_fused_matches_vanilla():
    from repro.core import viterbi_vanilla, erdos_renyi_hmm, random_emissions
    k1, k2 = jax.random.split(jax.random.key(5))
    hmm = erdos_renyi_hmm(k1, 128, edge_prob=0.4)
    em = random_emissions(k2, 33, 128)
    p1, s1 = ops.viterbi_decode_fused(hmm.log_pi, hmm.log_A, em)
    p2, s2 = viterbi_vanilla(hmm.log_pi, hmm.log_A, em)
    assert np.array_equal(np.asarray(p1), np.asarray(p2))
    np.testing.assert_allclose(float(s1), float(s2), rtol=1e-5)


@pytest.mark.parametrize("K,B,chunk", [(512, 64, 128), (300, 32, 128),
                                       (128, 128, 128), (256, 16, 64)])
def test_beam_step_kernel(K, B, chunk):
    k1, k2, k3, k4 = jax.random.split(jax.random.key(K + B), 4)
    A = jax.random.normal(k1, (K, K))
    em = jax.random.normal(k2, (K,))
    scores = jax.random.normal(k3, (B,))
    states = jax.random.permutation(k4, K)[:B].astype(jnp.int32)
    s, st, f = ops.beam_step(A, em, scores, states, chunk=chunk)
    sr, str_, fr = ref.beam_step_ref(A, em, scores, states)
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), atol=1e-5)
    assert np.array_equal(np.asarray(st), np.asarray(str_))
    assert np.array_equal(np.asarray(f), np.asarray(fr))
