"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs ref.py oracles."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize("I,K,J", [(8, 16, 128), (64, 128, 256), (37, 100, 200),
                                   (1, 512, 512), (128, 64, 384)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_tropical_matmul(I, K, J, dtype):
    k1, k2 = jax.random.split(jax.random.key(I * 1000 + J))
    a = jax.random.normal(k1, (I, K), dtype=jnp.float32).astype(dtype)
    b = jax.random.normal(k2, (K, J), dtype=jnp.float32).astype(dtype)
    v, g = ops.tropical_matmul(a, b)
    vr, gr = ref.tropical_matmul_ref(a, b)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(v, np.float32),
                               np.asarray(vr, np.float32), atol=tol, rtol=tol)
    assert np.array_equal(np.asarray(g), np.asarray(gr))


@pytest.mark.parametrize("T,K", [(16, 128), (33, 128), (24, 256), (7, 384)])
def test_viterbi_forward_kernel(T, K):
    k1, k2, k3 = jax.random.split(jax.random.key(T * 31 + K), 3)
    A = jax.random.normal(k1, (K, K))
    em = jax.random.normal(k2, (T, K))
    d0 = jax.random.normal(k3, (K,))
    psi, dT = ops.viterbi_forward(A, em, d0)
    psir, dTr = ref.viterbi_forward_ref(A, em, d0)
    assert np.array_equal(np.asarray(psi), np.asarray(psir))
    np.testing.assert_allclose(np.asarray(dT), np.asarray(dTr),
                               atol=1e-4, rtol=1e-5)


def test_viterbi_forward_large_k_fallback():
    """K not 128-aligned falls back to the XLA path, same results."""
    k1, k2, k3 = jax.random.split(jax.random.key(0), 3)
    K, T = 200, 12
    A = jax.random.normal(k1, (K, K))
    em = jax.random.normal(k2, (T, K))
    d0 = jax.random.normal(k3, (K,))
    psi, dT = ops.viterbi_forward(A, em, d0)
    psir, dTr = ref.viterbi_forward_ref(A, em, d0)
    assert np.array_equal(np.asarray(psi), np.asarray(psir))


@pytest.mark.parametrize("T", [7, 13, 31, 97])
def test_viterbi_forward_prime_lengths(T):
    """Odd T pads up to a bt multiple with tropical-identity steps instead of
    degrading the kernel to bt=1 tiling; results stay exact."""
    K = 128
    k1, k2, k3 = jax.random.split(jax.random.key(T), 3)
    A = jax.random.normal(k1, (K, K))
    em = jax.random.normal(k2, (T, K))
    d0 = jax.random.normal(k3, (K,))
    psi, dT = ops.viterbi_forward(A, em, d0)
    psir, dTr = ref.viterbi_forward_ref(A, em, d0)
    assert psi.shape == (T, K)
    assert np.array_equal(np.asarray(psi), np.asarray(psir))
    assert np.array_equal(np.asarray(dT), np.asarray(dTr))


def test_viterbi_decode_fused_prime_length_matches_vanilla():
    from repro.core import viterbi_vanilla, erdos_renyi_hmm, random_emissions
    k1, k2 = jax.random.split(jax.random.key(97))
    hmm = erdos_renyi_hmm(k1, 128, edge_prob=0.4)
    em = random_emissions(k2, 97, 128)          # prime T
    p1, s1 = ops.viterbi_decode_fused(hmm.log_pi, hmm.log_A, em)
    p2, s2 = viterbi_vanilla(hmm.log_pi, hmm.log_A, em)
    assert np.array_equal(np.asarray(p1), np.asarray(p2))
    np.testing.assert_allclose(float(s1), float(s2), rtol=1e-6)


def test_viterbi_forward_batch_ragged():
    """Batch-grid kernel with ragged lengths: per-sequence rows bit-identical
    to the single-sequence reference; pad rows are identity backpointers."""
    B, T, K = 4, 20, 128
    lengths = [20, 7, 1, 20]
    k1, k2, k3 = jax.random.split(jax.random.key(3), 3)
    A = jax.random.normal(k1, (K, K))
    em = jax.random.normal(k2, (B, T, K))
    d0 = jax.random.normal(k3, (B, K))
    psi, dT = ops.viterbi_forward_batch(A, em, d0, jnp.asarray(lengths))
    eye = np.arange(K, dtype=np.int32)
    for i, L in enumerate(lengths):
        psir, dTr = ref.viterbi_forward_ref(A, em[i, :L], d0[i])
        assert np.array_equal(np.asarray(psi[i, :L]), np.asarray(psir)), i
        assert np.array_equal(np.asarray(dT[i]), np.asarray(dTr)), i
        assert np.all(np.asarray(psi[i, L:]) == eye[None, :]), i


def test_viterbi_forward_batch_fallback_matches_kernel_semantics():
    """K not 128-aligned takes the vmapped masked XLA path, same results."""
    B, T, K = 3, 11, 100
    lengths = [11, 4, 1]
    k1, k2, k3 = jax.random.split(jax.random.key(4), 3)
    A = jax.random.normal(k1, (K, K))
    em = jax.random.normal(k2, (B, T, K))
    d0 = jax.random.normal(k3, (B, K))
    psi, dT = ops.viterbi_forward_batch(A, em, d0, jnp.asarray(lengths))
    for i, L in enumerate(lengths):
        psir, dTr = ref.viterbi_forward_ref(A, em[i, :L], d0[i])
        assert np.array_equal(np.asarray(psi[i, :L]), np.asarray(psir)), i
        assert np.array_equal(np.asarray(dT[i]), np.asarray(dTr)), i


def test_viterbi_decode_fused_batch_matches_loop():
    from repro.core import erdos_renyi_hmm, random_emissions
    B, T, K = 4, 19, 128
    lengths = [19, 8, 1, 13]
    k1, k2 = jax.random.split(jax.random.key(6))
    hmm = erdos_renyi_hmm(k1, K, edge_prob=0.4)
    em = random_emissions(k2, B * T, K).reshape(B, T, K)
    paths, scores = ops.viterbi_decode_fused_batch(
        hmm.log_pi, hmm.log_A, em, jnp.asarray(lengths))
    for i, L in enumerate(lengths):
        p, s = ops.viterbi_decode_fused(hmm.log_pi, hmm.log_A, em[i, :L])
        assert np.array_equal(np.asarray(paths[i, :L]), np.asarray(p)), i
        assert float(scores[i]) == float(s), i


def test_viterbi_decode_fused_matches_vanilla():
    from repro.core import viterbi_vanilla, erdos_renyi_hmm, random_emissions
    k1, k2 = jax.random.split(jax.random.key(5))
    hmm = erdos_renyi_hmm(k1, 128, edge_prob=0.4)
    em = random_emissions(k2, 33, 128)
    p1, s1 = ops.viterbi_decode_fused(hmm.log_pi, hmm.log_A, em)
    p2, s2 = viterbi_vanilla(hmm.log_pi, hmm.log_A, em)
    assert np.array_equal(np.asarray(p1), np.asarray(p2))
    np.testing.assert_allclose(float(s1), float(s2), rtol=1e-5)


@pytest.mark.parametrize("K,B,chunk", [(512, 64, 128), (300, 32, 128),
                                       (128, 128, 128), (256, 16, 64)])
def test_beam_step_kernel(K, B, chunk):
    k1, k2, k3, k4 = jax.random.split(jax.random.key(K + B), 4)
    A = jax.random.normal(k1, (K, K))
    em = jax.random.normal(k2, (K,))
    scores = jax.random.normal(k3, (B,))
    states = jax.random.permutation(k4, K)[:B].astype(jnp.int32)
    s, st, f = ops.beam_step(A, em, scores, states, chunk=chunk)
    sr, str_, fr = ref.beam_step_ref(A, em, scores, states)
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), atol=1e-5)
    assert np.array_equal(np.asarray(st), np.asarray(str_))
    assert np.array_equal(np.asarray(f), np.asarray(fr))
