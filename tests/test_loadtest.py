"""The end-to-end scale harness: deterministic load generation, the harness
event loop over BatchScheduler + StreamMux, and the differential serving
oracle.

Everything here runs tier-1-fast (small K/T, a handful of requests); the
fault drills built on the same harness live in test_drills.py behind the
`drill` marker."""

import dataclasses
import json

import numpy as np
import pytest

from repro.launch.loadtest import (LoadConfig, LoadHarness, VirtualClock,
                                   make_workload, oracle_check,
                                   peak_concurrency, resolve_spec,
                                   run_inflight_compare)

SMOKE = LoadConfig(seed=3, requests=10, states=16, stream_frac=0.3,
                   lengths=(8, 18, 30), buckets=(32,), max_batch=4,
                   stream_block=8, stream_chunk=4, method="vanilla")


# ---------------------------------------------------------------------------
# Clock and generator
# ---------------------------------------------------------------------------

def test_virtual_clock():
    clock = VirtualClock()
    clock.advance(1.5)
    clock.advance_to(1.0)          # never goes backwards
    assert clock.now() == 1.5
    clock.advance_to(2.0)
    assert clock.now() == 2.0
    with pytest.raises(ValueError):
        clock.advance(-0.1)


def test_config_validation():
    with pytest.raises(ValueError, match="stream_frac"):
        LoadConfig(stream_frac=1.5)
    with pytest.raises(ValueError, match="bucket"):
        LoadConfig(lengths=(256,), buckets=(64,))


def test_workload_deterministic_from_seed():
    """The whole trace — times, kinds, payload bytes — reproduces from the
    seed; a different seed produces a different trace."""
    w1, w2 = make_workload(SMOKE), make_workload(SMOKE)
    assert len(w1.events) == len(w2.events)
    for a, b in zip(w1.events, w2.events):
        assert (a.t, a.seq, a.kind, a.rid) == (b.t, b.seq, b.kind, b.rid)
        if a.frames is not None:
            assert np.array_equal(a.frames, b.frames)
    for rid in w1.payloads:
        assert np.array_equal(w1.payloads[rid], w2.payloads[rid])
    w3 = make_workload(dataclasses.replace(SMOKE, seed=SMOKE.seed + 1))
    assert any(a.t != b.t for a, b in zip(w1.events, w3.events))


def test_workload_shape():
    w = make_workload(SMOKE)
    assert set(w.kinds.values()) == {"offline", "stream"}
    assert all(p.shape[0] in SMOKE.lengths and p.shape[1] == SMOKE.states
               for p in w.payloads.values())
    ts = [e.t for e in w.events]
    assert ts == sorted(ts)
    # streaming requests decompose into open -> feeds covering T -> finish
    for rid, kind in w.kinds.items():
        evs = [e for e in w.events if e.rid == rid]
        if kind == "stream":
            assert [e.kind for e in evs][0] == "open"
            assert [e.kind for e in evs][-1] == "finish"
            fed = sum(e.frames.shape[0] for e in evs if e.kind == "feed")
            assert fed == w.payloads[rid].shape[0]
        else:
            assert [e.kind for e in evs] == ["offline"]


def test_resolve_spec_budget_path():
    spec, p = resolve_spec(SMOKE)
    assert p is None and spec.method == "vanilla"
    spec_b, plan_b = resolve_spec(dataclasses.replace(SMOKE, budget_kb=64.0))
    assert plan_b is not None and plan_b.spec == spec_b
    assert plan_b.state_bytes <= 64 * 1024


# ---------------------------------------------------------------------------
# Harness end-to-end
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def smoke_report():
    return LoadHarness(SMOKE).run()


def test_harness_delivers_everything_exactly_once(smoke_report):
    r = smoke_report["requests"]
    assert r["delivered"] == r["total"] == SMOKE.requests
    assert r["duplicates"] == 0
    assert r["offline"] + r["stream"] == r["total"]


def test_harness_oracle_passes(smoke_report):
    """The tentpole invariant: every served path — batched, padded, muxed —
    is bit-identical to an unbatched reference decode."""
    ora = smoke_report["oracle"]
    assert ora["ok"]
    assert ora["offline"]["mismatches"] == []
    assert ora["stream"]["mismatches"] == []
    assert (ora["offline"]["checked"] + ora["stream"]["checked"]
            == SMOKE.requests)
    assert ora["offline"]["exact"]


def test_harness_reports_throughput_and_percentiles(smoke_report):
    tp = smoke_report["throughput"]
    assert tp["requests_per_s"] > 0 and tp["frames_per_s"] > 0
    off = smoke_report["latency_s"]["offline"]
    assert off is not None and 0 <= off["p50"] <= off["p99"] <= off["max"]
    assert smoke_report["scheduler"]["batches"] >= 1
    assert smoke_report["stream"]["peak_live_state_bytes"] > 0


def test_report_is_json_serialisable(smoke_report):
    blob = json.dumps(smoke_report, default=str)
    back = json.loads(blob)
    assert back["config"]["seed"] == SMOKE.seed
    for key in ("config", "spec", "requests", "throughput", "latency_s",
                "scheduler", "stream", "oracle"):
        assert key in back


def test_budget_planned_harness_passes_oracle():
    """The serve.py --budget-kb path, under load: budget -> plan -> spec ->
    scheduler, still bit-identical to the oracle."""
    cfg = dataclasses.replace(SMOKE, budget_kb=8.0, requests=6)
    h = LoadHarness(cfg)
    report = h.run()
    assert report["spec"]["planned_why"] is not None
    assert report["oracle"]["ok"]
    assert report["requests"]["delivered"] == cfg.requests


# ---------------------------------------------------------------------------
# Inflight vs bucketed comparison
# ---------------------------------------------------------------------------

def test_harness_inflight_mode_passes_oracle():
    """The harness event loop with sessions routed through the inflight
    tier instead of bucketing: still exactly-once, still oracle-clean."""
    cfg = dataclasses.replace(SMOKE, stream_frac=1.0, requests=8,
                              inflight=True, inflight_slots=4)
    report = LoadHarness(cfg).run()
    assert report["oracle"]["ok"]
    assert report["requests"]["delivered"] == cfg.requests
    assert report["inflight"]["stats"]["finished"] == cfg.requests
    assert report["inflight"]["block_latency_s"]["count"] > 0


def test_run_inflight_compare_smoke():
    """Both sides of the A/B run the same seeded workload, both pass the
    oracle, and session churn causes zero retraces of the slot step."""
    cfg = dataclasses.replace(SMOKE, requests=8, inflight=True,
                              inflight_slots=4)
    rep = run_inflight_compare(cfg)
    assert rep["oracle_ok"]
    assert rep["retraces"] == 0
    assert rep["peak_concurrent_sessions"] >= 1
    for side in ("bucketed", "inflight"):
        assert rep[side]["oracle_ok"]
        assert rep[side]["stream_stats"]["finished"] == cfg.requests
    assert rep["inflight"]["slo"]["stats"]["finished"] >= cfg.requests
    assert rep["p99_completion_s"]["bucketed"] > 0
    assert rep["p99_completion_s"]["inflight"] > 0
    blob = json.dumps(rep, default=str)
    assert json.loads(blob)["retraces"] == 0


def test_peak_concurrency():
    w = make_workload(dataclasses.replace(SMOKE, stream_frac=1.0))
    assert 1 <= peak_concurrency(w) <= SMOKE.requests


# ---------------------------------------------------------------------------
# The oracle actually catches corruption
# ---------------------------------------------------------------------------

def test_oracle_flags_corrupted_path():
    """Negative control: corrupt one frame of one served path and the oracle
    must report it — otherwise the whole harness is a rubber stamp."""
    cfg = dataclasses.replace(SMOKE, stream_frac=0.0, requests=6)
    h = LoadHarness(cfg)
    orig = h.sched.fn

    def corrupting(padded, lengths):
        paths, scores = orig(padded, lengths)
        paths = np.asarray(paths).copy()
        paths[0, 0] = (paths[0, 0] + 1) % cfg.states   # one wrong frame
        return paths, scores

    h.sched.fn = corrupting
    report = h.run()
    assert not report["oracle"]["ok"]
    whats = {m["what"] for m in report["oracle"]["offline"]["mismatches"]}
    assert "path_vs_looped_spec" in whats


def test_oracle_flags_wrong_score():
    cfg = dataclasses.replace(SMOKE, stream_frac=0.0, requests=4)
    w = make_workload(cfg)
    spec, _ = resolve_spec(cfg)
    from repro.core import viterbi_vanilla
    results = {}
    for rid in list(w.payloads)[:2]:
        p, s = viterbi_vanilla(w.hmm.log_pi, w.hmm.log_A, w.payloads[rid])
        results[rid] = (np.asarray(p), float(s))
    ora = oracle_check(spec, w.hmm, w.payloads, results)
    assert ora["ok"]
    rid0 = next(iter(results))
    results[rid0] = (results[rid0][0], results[rid0][1] + 1.0)
    ora2 = oracle_check(spec, w.hmm, w.payloads, results)
    assert not ora2["ok"]
    assert any(m["rid"] == rid0 for m in ora2["mismatches"])
