"""The typed DecodeSpec / planner / ViterbiDecoder API.

Pins the PR-4 redesign contract:
  * specs validate eagerly and are hashable (jit-cache keys);
  * the planner reproduces the adaptive_edge degradation ladder and never
    picks a larger-footprint plan for a smaller budget;
  * the legacy `viterbi_decode(method=..., **kw)` shim is bit-identical to
    `ViterbiDecoder` built from the equivalent spec — for every method, and
    through the batched/ragged and mesh-sharded entry points;
  * ignored legacy tunables warn instead of being silently dropped.
"""

import dataclasses
import warnings

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (
    erdos_renyi_hmm, random_emissions, viterbi_decode, viterbi_decode_batch,
    ViterbiDecoder, DecodePlan, plan, ResourceBudget,
    decoder_state_bytes, spec_state_bytes, spec_from_tunables,
    SPEC_BY_METHOD, METHODS, BATCH_METHODS,
    VanillaSpec, CheckpointSpec, FlashSpec, FlashBSSpec, BeamStaticSpec,
    BeamStaticMPSpec, AssocSpec, FusedSpec, OnlineSpec, OnlineBeamSpec,
)
from repro.runtime.jaxcompat import make_mesh


@pytest.fixture(scope="module")
def problem():
    key = jax.random.key(42)
    k1, k2 = jax.random.split(key)
    hmm = erdos_renyi_hmm(k1, 48, edge_prob=0.3)
    em = random_emissions(k2, 96, 48)
    return hmm, em


# ---------------------------------------------------------------------------
# Spec construction: validation, hashability, registry
# ---------------------------------------------------------------------------

def test_every_method_has_a_spec():
    assert set(SPEC_BY_METHOD) == set(METHODS)
    for method, cls in SPEC_BY_METHOD.items():
        assert cls.method == method
        assert dataclasses.is_dataclass(cls)


@pytest.mark.parametrize("bad", [
    lambda: FlashSpec(parallelism=0),
    lambda: FlashSpec(parallelism=-2),
    lambda: FlashSpec(lanes=0),
    lambda: FlashBSSpec(beam_width=0),
    lambda: FlashBSSpec(chunk=0),
    lambda: BeamStaticSpec(beam_width=-1),
    lambda: BeamStaticMPSpec(parallelism=0),
    lambda: CheckpointSpec(seg_len=0),
    lambda: FusedSpec(bt=0),
    lambda: OnlineSpec(stream_chunk=0),
    lambda: OnlineBeamSpec(max_lag=0),
    lambda: ResourceBudget(memory_bytes=0),
    lambda: ResourceBudget(latency_hint="speed"),
])
def test_nonsense_rejected_eagerly(bad):
    with pytest.raises(ValueError):
        bad()


def test_unknown_tunables_fail_loudly():
    # the legacy dispatch silently dropped these; the spec cannot express them
    with pytest.raises(TypeError):
        VanillaSpec(beam_width=4)
    with pytest.raises(TypeError):
        FlashSpec(beam_width=4)
    with pytest.raises(TypeError):
        FlashBSSpec(seg_len=3)


def test_specs_hashable_and_frozen():
    a = FlashBSSpec(parallelism=4, beam_width=64)
    b = FlashBSSpec(parallelism=4, beam_width=64)
    c = FlashBSSpec(parallelism=4, beam_width=32)
    assert a == b and hash(a) == hash(b)
    assert a != c
    assert {a: 1, c: 2}[b] == 1          # usable as a cache key
    with pytest.raises(dataclasses.FrozenInstanceError):
        a.beam_width = 16


def test_spec_from_tunables_routes_and_reports_ignored():
    spec, ignored = spec_from_tunables(
        "flash", {"parallelism": 4, "beam_width": 9, "seg_len": 2})
    assert spec == FlashSpec(parallelism=4)
    assert set(ignored) == {"beam_width", "seg_len"}
    with pytest.raises(ValueError):
        spec_from_tunables("nope", {})


# ---------------------------------------------------------------------------
# Legacy shim: deprecation warning on ignored tunables
# ---------------------------------------------------------------------------

def test_legacy_ignored_tunable_warns(problem):
    hmm, em = problem
    with pytest.warns(DeprecationWarning, match="beam_width"):
        viterbi_decode(em, hmm.log_pi, hmm.log_A, method="vanilla",
                       beam_width=8)
    with pytest.warns(DeprecationWarning, match="seg_len"):
        viterbi_decode(em, hmm.log_pi, hmm.log_A, method="flash",
                       parallelism=4, seg_len=10)


def test_legacy_consumed_tunables_do_not_warn(problem):
    hmm, em = problem
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        viterbi_decode(em, hmm.log_pi, hmm.log_A, method="flash_bs",
                       parallelism=4, beam_width=16, chunk=16)


# ---------------------------------------------------------------------------
# Planner: cost model, ladder, monotonicity
# ---------------------------------------------------------------------------

def test_cost_model_matches_spec_view():
    assert (spec_state_bytes(FlashSpec(parallelism=4), 512, 512)
            == decoder_state_bytes("flash", 512, 512, P=4))
    assert (spec_state_bytes(FlashBSSpec(parallelism=2, beam_width=64),
                             512, 512)
            == decoder_state_bytes("flash_bs", 512, 512, P=2, B=64))


def test_benchmarks_reexport_cost_model():
    # benchmarks/examples import the cost model FROM core, never the reverse
    from benchmarks.common import decoder_state_bytes as bench_view
    assert bench_view is decoder_state_bytes


def test_plan_reproduces_adaptive_edge_ladder():
    # the exact decisions the old examples/adaptive_edge.choose_config made
    p64 = plan(512, 512, ResourceBudget(memory_bytes=64 * 1024))
    assert p64.spec == FlashSpec(parallelism=8)
    assert "exact, P=8" in p64.why

    p8 = plan(512, 512, ResourceBudget(memory_bytes=8 * 1024))
    assert p8.spec == FlashSpec(parallelism=1)
    assert "exact, P=1" in p8.why

    # below the exact floor the beam ladder fires, then the floor config
    pbeam = plan(512, 512, 1024)
    assert isinstance(pbeam.spec, FlashBSSpec)
    assert pbeam.state_bytes <= 1024
    pfloor = plan(512, 512, 1)
    assert pfloor.spec == FlashBSSpec(parallelism=1, beam_width=16)
    assert pfloor.why.startswith("floor")
    assert "exceeds budget" in pfloor.why     # the why never claims a false fit


def test_plan_rejects_nonpositive_batch():
    with pytest.raises(ValueError, match="batch"):
        plan(512, 512, 1024, batch=0)
    with pytest.raises(ValueError, match="batch"):
        plan(512, 512, 1024, batch=-3)


def test_plan_respects_budget_cost_model():
    for kb in (512, 64, 8, 2, 1):
        budget = kb * 1024
        p = plan(512, 512, budget)
        assert isinstance(p, DecodePlan)
        assert p.state_bytes == spec_state_bytes(p.spec, 512, 512)
        if not p.why.startswith("floor"):
            assert p.state_bytes <= budget


def test_plan_monotone_in_budget():
    # a smaller budget never yields a larger-footprint plan
    budgets = [2 ** b for b in range(8, 22)]
    footprints = [plan(512, 512, b).state_bytes for b in budgets]
    assert footprints == sorted(footprints)


def test_plan_batch_scales_footprint():
    single = plan(512, 512, 64 * 1024)
    batched = plan(512, 512, 64 * 1024, batch=8)
    assert batched.state_bytes == 8 * spec_state_bytes(batched.spec, 512, 512)
    # the batched plan had to degrade further down the ladder
    assert batched.state_bytes <= 64 * 1024
    assert (spec_state_bytes(batched.spec, 512, 512)
            <= spec_state_bytes(single.spec, 512, 512))
    # planned-for-batch specs must be batch-executable
    assert batched.spec.batch_method in BATCH_METHODS


def test_plan_memory_hint_prefers_smallest_exact():
    p = plan(512, 512, ResourceBudget(memory_bytes=1 << 20,
                                      latency_hint="memory"))
    assert p.spec == FlashSpec(parallelism=1)
    p_lat = plan(512, 512, ResourceBudget(memory_bytes=1 << 20))
    assert p_lat.spec == FlashSpec(parallelism=16)


def test_plan_unlimited_budget_is_latency_optimal():
    assert plan(512, 512).spec == FlashSpec(parallelism=16)


# ---------------------------------------------------------------------------
# Bit-identity: legacy viterbi_decode vs ViterbiDecoder, every method
# ---------------------------------------------------------------------------

# modest tunables so beams/streaming take their real code paths at K=48
_TUNABLES = {
    "vanilla": {}, "checkpoint": {"seg_len": 12},
    "flash": {"parallelism": 4},
    "flash_bs": {"parallelism": 4, "beam_width": 16, "chunk": 16},
    "beam_static": {"beam_width": 16},
    "beam_static_mp": {"beam_width": 16, "parallelism": 4},
    "assoc": {}, "fused": {},
    "online": {"stream_chunk": 32},
    "online_beam": {"beam_width": 16, "chunk": 16, "stream_chunk": 32},
}


@pytest.mark.parametrize("method", METHODS)
def test_decoder_bit_identical_to_legacy(problem, method):
    hmm, em = problem
    kw = _TUNABLES[method]
    p_legacy, s_legacy = viterbi_decode(em, hmm.log_pi, hmm.log_A,
                                        method=method, **kw)
    spec, ignored = spec_from_tunables(method, kw)
    assert not ignored
    dec = ViterbiDecoder(spec, hmm.log_pi, hmm.log_A)
    p_spec, s_spec = dec.decode(em)
    assert np.array_equal(np.asarray(p_legacy), np.asarray(p_spec))
    assert np.asarray(s_legacy) == np.asarray(s_spec)   # bit-identical


@pytest.mark.parametrize("method", BATCH_METHODS)
def test_decode_batch_bit_identical_to_legacy_batch(problem, method):
    hmm, em = problem
    T, K = em.shape
    ems = jnp.stack([em, em[::-1], em * 0.5])
    lengths = jnp.asarray([T, T // 2, T // 3], jnp.int32)
    kw = {k: v for k, v in _TUNABLES[method].items()}
    p_legacy, s_legacy = viterbi_decode_batch(ems, hmm.log_pi, hmm.log_A,
                                              lengths, method=method, **kw)
    spec, _ = spec_from_tunables(method, kw)
    dec = ViterbiDecoder(spec, hmm.log_pi, hmm.log_A)
    p_spec, s_spec = dec.decode_batch(ems, lengths)
    assert np.array_equal(np.asarray(p_legacy), np.asarray(p_spec))
    assert np.array_equal(np.asarray(s_legacy), np.asarray(s_spec))


@pytest.mark.parametrize("method", ("vanilla", "flash", "fused"))
def test_decode_sharded_bit_identical(problem, method):
    hmm, em = problem
    T, K = em.shape
    mesh = make_mesh((1,), ("data",))
    ems = jnp.stack([em, em[::-1], em * 0.5])       # B=3: exercises dummy pad
    lengths = jnp.asarray([T, T // 2, T // 3], jnp.int32)
    spec, _ = spec_from_tunables(method, _TUNABLES[method])
    dec = ViterbiDecoder(spec, hmm.log_pi, hmm.log_A)
    p_ref, s_ref = dec.decode_batch(ems, lengths)
    p_sh, s_sh = dec.decode_sharded(ems, lengths, mesh=mesh)
    assert p_sh.shape == p_ref.shape                 # dummies sliced back off
    assert np.array_equal(np.asarray(p_ref), np.asarray(p_sh))
    assert np.array_equal(np.asarray(s_ref), np.asarray(s_sh))


def test_decode_batch_ragged_matches_single(problem):
    hmm, em = problem
    T, K = em.shape
    spec = FlashSpec(parallelism=4)
    dec = ViterbiDecoder(spec, hmm.log_pi, hmm.log_A)
    ems = jnp.stack([em, em])
    lengths = jnp.asarray([T, T // 2], jnp.int32)
    paths, scores = dec.decode_batch(ems, lengths)
    for i, L in enumerate([T, T // 2]):
        p1, s1 = dec.decode(em[:L])
        assert np.array_equal(np.asarray(paths[i, :L]), np.asarray(p1))
        assert np.isclose(float(scores[i]), float(s1), rtol=1e-6)


def test_decode_batch_rejects_unbatchable_spec(problem):
    hmm, em = problem
    dec = ViterbiDecoder(AssocSpec(), hmm.log_pi, hmm.log_A)
    with pytest.raises(ValueError, match="no batched path"):
        dec.decode_batch(jnp.stack([em]))


def test_decode_batch_validates_lengths_eagerly(problem):
    hmm, em = problem
    dec = ViterbiDecoder(VanillaSpec(), hmm.log_pi, hmm.log_A)
    with pytest.raises(ValueError, match="lengths"):
        dec.decode_batch(jnp.stack([em]), lengths=jnp.asarray([0]))


def test_streaming_spec_roundtrip(problem):
    hmm, em = problem
    dec = ViterbiDecoder(OnlineSpec(), hmm.log_pi, hmm.log_A)
    sdec = dec.make_streaming()
    sdec.feed(np.asarray(em))
    sdec.flush()
    p_ref, _ = viterbi_decode(em, hmm.log_pi, hmm.log_A, method="vanilla")
    assert np.array_equal(np.asarray(sdec.path), np.asarray(p_ref))
    with pytest.raises(ValueError, match="not a streaming spec"):
        ViterbiDecoder(VanillaSpec(), hmm.log_pi, hmm.log_A).make_streaming()
