"""Continuous inflight batching: the slot-pool serving tier.

The load-bearing invariants, in rough order of importance:

  * every delivered path is **bit-identical** to the looped unbatched
    `session_spec(sid).run` oracle — exact sessions at any feed granularity,
    bounded-lag sessions at the oracle's block boundaries;
  * `collect` is exactly-once: concatenating every drain plus the finish
    tail reproduces the full path, and a second drain is empty;
  * slot reuse never leaks state between consecutive occupants;
  * admission never lets projected session bytes exceed the `ResourceBudget`,
    degrading down the lag ladder before queueing and queueing before
    rejecting;
  * the queue is FIFO within a priority class;
  * join/leave churn never recompiles the fixed-shape slot step.
"""

import numpy as np
import jax
import pytest

from repro.core import (ResourceBudget, erdos_renyi_hmm, random_emissions,
                        online_session_bytes, viterbi_vanilla)
from repro.serving import (AdmissionRejected, InflightScheduler, StreamConfig,
                           StreamMux)
from repro.serving.inflight import inflight_jit_fns


@pytest.fixture(scope="module")
def hmm():
    return erdos_renyi_hmm(jax.random.key(7), 24, edge_prob=0.4)


def _ems(hmm, lengths, seed=0, scale=2.0):
    key = jax.random.key(seed)
    return [np.asarray(random_emissions(k, T, hmm.log_pi.shape[0],
                                        scale=scale))
            for k, T in zip(jax.random.split(key, len(lengths)), lengths)]


# -- bit-identity against the unbatched oracle ------------------------------

def test_exact_sessions_bit_identical_any_granularity(hmm):
    """Exact sessions fed at ragged granularities across a shared pool must
    each reproduce the offline optimal decode bit-for-bit."""
    lengths = [37, 80, 9, 64, 33]
    ems = _ems(hmm, lengths)
    sched = InflightScheduler(hmm.log_pi, hmm.log_A, max_slots=3, block=16)
    sids = [sched.submit() for _ in ems]
    cursors = [0] * len(ems)
    feeds = [5, 16, 3, 16, 11]
    while any(c < e.shape[0] for c, e in zip(cursors, ems)):
        for i, sid in enumerate(sids):
            c, step = cursors[i], feeds[i]
            if c < ems[i].shape[0]:
                sched.feed(sid, ems[i][c:c + step])
                cursors[i] = min(c + step, ems[i].shape[0])
        sched.pump()
    for sid, em in zip(sids, ems):
        path, score = sched.finish(sid)
        ref_path, ref_score = viterbi_vanilla(hmm.log_pi, hmm.log_A, em)
        assert np.array_equal(path, np.asarray(ref_path))
        assert float(score) == float(ref_score)


@pytest.mark.parametrize("max_lag", [2, 8])
def test_lagged_sessions_match_online_spec_oracle(hmm, max_lag):
    """Bounded-lag sessions must replicate the forced-flush boundaries of
    `OnlineSpec(stream_chunk=block, max_lag=L).run` exactly — weak-evidence
    emissions so forced flushes actually fire."""
    ems = _ems(hmm, [70, 41, 66], seed=3, scale=0.2)
    sched = InflightScheduler(hmm.log_pi, hmm.log_A, max_slots=3, block=8)
    sids = [sched.submit(max_lag=max_lag) for _ in ems]
    for sid, em in zip(sids, ems):
        sched.feed(sid, em)
    sched.pump()
    forced = 0
    for sid, em in zip(sids, ems):
        spec = sched.session_spec(sid)
        assert spec.stream_chunk == 8 and spec.max_lag == max_lag
        path, score = sched.finish(sid)
        ref_path, ref_score = spec.run(hmm.log_pi, hmm.log_A, em)
        assert np.array_equal(path, np.asarray(ref_path))
        assert float(score) == float(ref_score)
        forced += sched._sessions[sid].dec.stats["forced"]
    assert forced > 0, "workload never forced a flush; oracle untested"


def test_mixed_exact_and_lagged_pool(hmm):
    """Exact and bounded-lag sessions sharing the same batched state must
    not perturb each other."""
    ems = _ems(hmm, [50, 50, 50, 50], seed=9, scale=0.3)
    sched = InflightScheduler(hmm.log_pi, hmm.log_A, max_slots=4, block=8)
    lags = [None, 4, None, 4]
    sids = [sched.submit(max_lag=m) for m in lags]
    for sid, em in zip(sids, ems):
        sched.feed(sid, em)
        sched.pump()
    for sid, em in zip(sids, ems):
        path, score = sched.finish(sid)
        ref_path, ref_score = sched.session_spec(sid).run(
            hmm.log_pi, hmm.log_A, em)
        assert np.array_equal(path, np.asarray(ref_path))
        assert float(score) == float(ref_score)


# -- delivery semantics -----------------------------------------------------

def test_collect_is_exactly_once(hmm):
    em = _ems(hmm, [61])[0]
    sched = InflightScheduler(hmm.log_pi, hmm.log_A, max_slots=2, block=16)
    sid = sched.submit()
    got = []
    for s in range(0, 61, 16):
        sched.feed(sid, em[s:s + 16])
        sched.pump()
        seg = sched.collect(sid)
        got.append(seg)
        assert sched.collect(sid).shape[0] == 0     # drained: second is empty
    path, _ = sched.finish(sid)
    got.append(sched.collect(sid))                  # the flush tail
    assert sched.collect(sid).shape[0] == 0
    assert np.array_equal(np.concatenate(got), path)
    ref_path, _ = viterbi_vanilla(hmm.log_pi, hmm.log_A, em)
    assert np.array_equal(path, np.asarray(ref_path))


def test_finish_is_idempotent_and_feed_after_finish_raises(hmm):
    em = _ems(hmm, [20])[0]
    sched = InflightScheduler(hmm.log_pi, hmm.log_A, max_slots=1, block=8)
    sid = sched.submit()
    sched.feed(sid, em)
    first = sched.finish(sid)
    again = sched.finish(sid)
    assert np.array_equal(first[0], again[0]) and first[1] == again[1]
    with pytest.raises(RuntimeError, match="finished"):
        sched.feed(sid, em[:1])


def test_slot_reuse_never_leaks_state(hmm):
    """Three consecutive occupants of the single slot each decode as if the
    pool were freshly built."""
    ems = _ems(hmm, [45, 30, 77], seed=5)
    sched = InflightScheduler(hmm.log_pi, hmm.log_A, max_slots=1, block=16)
    for em in ems:
        sid = sched.submit()
        assert sched.live_sessions() == [sid]       # single slot, reused
        sched.feed(sid, em)
        sched.pump()
        path, score = sched.finish(sid)
        ref_path, ref_score = viterbi_vanilla(hmm.log_pi, hmm.log_A, em)
        assert np.array_equal(path, np.asarray(ref_path))
        assert float(score) == float(ref_score)


# -- admission control ------------------------------------------------------

def test_admission_never_exceeds_budget(hmm):
    K, block = 24, 8
    per = online_session_bytes(K, block, max_lag=32)
    cap = 2 * per + per // 2                        # fits 2 requested, not 3
    sched = InflightScheduler(hmm.log_pi, hmm.log_A, max_slots=8, block=block,
                              budget=ResourceBudget(memory_bytes=cap),
                              default_max_lag=32)
    sids = [sched.submit() for _ in range(5)]
    assert sched.admitted_bytes() <= cap
    ems = _ems(hmm, [40] * 5, seed=11)
    for sid, em in zip(sids, ems):
        sched.feed(sid, em)
        sched.pump()
        assert sched.admitted_bytes() <= cap
    for sid, em in zip(sids, ems):
        path, _ = sched.finish(sid)
        assert sched.admitted_bytes() <= cap
        ref_path, _ = sched.session_spec(sid).run(hmm.log_pi, hmm.log_A, em)
        assert np.array_equal(path, np.asarray(ref_path))
    assert sched.admitted_bytes() == 0
    # the budget actually bit: some sessions had to wait or degrade
    assert sched.stats["queued_peak"] > 0 or sched.stats["degraded"] > 0


def test_admission_degrades_before_queueing(hmm):
    """A session whose requested lag doesn't fit is degraded down the ladder
    (tighter max_lag = smaller window) instead of being parked."""
    K, block = 24, 8
    cap = online_session_bytes(K, block, max_lag=64)
    sched = InflightScheduler(hmm.log_pi, hmm.log_A, max_slots=2, block=block,
                              budget=ResourceBudget(memory_bytes=cap))
    sid = sched.submit(max_lag=1024)                # too wide as requested
    sess = sched._sessions[sid]
    assert sess.slot is not None                    # admitted, not queued
    assert sess.max_lag is not None and sess.max_lag < 1024
    assert sched.stats["degraded"] == 1


def test_admission_rejects_impossible_session(hmm):
    cap = online_session_bytes(24, 8, max_lag=8) - 1   # below tightest rung
    sched = InflightScheduler(hmm.log_pi, hmm.log_A, max_slots=2, block=8,
                              budget=ResourceBudget(memory_bytes=cap))
    with pytest.raises(AdmissionRejected):
        sched.submit()
    assert sched.stats["rejected"] == 1


def test_queued_session_still_finishes(hmm):
    """A session the budget never let into the pool is decoded at finish via
    the unbatched overflow path — liveness under overload."""
    K, block = 24, 8
    cap = online_session_bytes(K, block, max_lag=8)    # exactly one session
    sched = InflightScheduler(hmm.log_pi, hmm.log_A, max_slots=4, block=block,
                              budget=ResourceBudget(memory_bytes=cap),
                              default_max_lag=8)
    a, b = sched.submit(), sched.submit()
    assert sched.queued_sessions() == [b]
    ems = _ems(hmm, [30, 30], seed=13)
    sched.feed(a, ems[0])
    sched.feed(b, ems[1])
    sched.pump()
    path_b, _ = sched.finish(b)                        # finished while queued
    assert sched.stats["overflow_finishes"] == 1
    ref_b, _ = sched.session_spec(b).run(hmm.log_pi, hmm.log_A, ems[1])
    assert np.array_equal(path_b, np.asarray(ref_b))
    path_a, _ = sched.finish(a)
    ref_a, _ = sched.session_spec(a).run(hmm.log_pi, hmm.log_A, ems[0])
    assert np.array_equal(path_a, np.asarray(ref_a))


def test_fifo_within_priority_class(hmm):
    """With one slot, same-class sessions attach strictly in arrival order;
    a lower-value priority always preempts the queue head position."""
    K, block = 24, 8
    cap = online_session_bytes(K, block, max_lag=8)
    sched = InflightScheduler(hmm.log_pi, hmm.log_A, max_slots=1, block=block,
                              budget=ResourceBudget(memory_bytes=cap),
                              default_max_lag=8)
    first = sched.submit(priority=1)                  # takes the slot
    q1 = sched.submit(priority=1)
    q2 = sched.submit(priority=1)
    hi = sched.submit(priority=0)                     # better class, arrives last
    em = _ems(hmm, [12])[0]
    attach_order = []
    for _ in range(4):
        live = sched.live_sessions()
        assert len(live) == 1
        sid = live[0]
        attach_order.append(sid)
        sched.feed(sid, em)
        sched.finish(sid)
    assert attach_order == [first, hi, q1, q2]


# -- mux routing ------------------------------------------------------------

def test_mux_routes_online_sessions_into_inflight(hmm):
    cfg = StreamConfig()
    sched = InflightScheduler(hmm.log_pi, hmm.log_A, max_slots=2, block=16)
    mux = StreamMux(hmm.log_pi, hmm.log_A, cfg, inflight=sched)
    em = _ems(hmm, [50])[0]
    sid = mux.open()
    got = []
    for s in range(0, 50, 16):
        out = mux.feed(sid, em[s:s + 16])
        got.append(out["committed"])
    path, score = mux.finish(sid)
    assert mux.stats["routed_inflight"] == 1
    ref_path, ref_score = viterbi_vanilla(hmm.log_pi, hmm.log_A, em)
    assert np.array_equal(path, np.asarray(ref_path))
    assert float(score) == float(ref_score)
    prefix = np.concatenate([g for g in got if g.shape[0]] or
                            [np.zeros(0, np.int32)])
    assert np.array_equal(prefix, path[:prefix.shape[0]])


def test_midflight_join_served_within_one_block(hmm):
    """The head-of-line regression: a session joining while another is
    mid-flight must get commits after its first fed block — not after the
    incumbent's bucket drains (the old bucketing behavior)."""
    cfg = StreamConfig()
    sched = InflightScheduler(hmm.log_pi, hmm.log_A, max_slots=4, block=16)
    mux = StreamMux(hmm.log_pi, hmm.log_A, cfg, inflight=sched)
    ems = _ems(hmm, [200, 40], seed=21)
    incumbent = mux.open()
    mux.feed(incumbent, ems[0][:64])                # mid-flight, far from done
    joiner = mux.open()
    out = mux.feed(joiner, ems[1][:16])             # exactly one block
    assert out["n_committed"] > 0, (
        "joining session starved behind the incumbent: served per-bucket, "
        "not per-block")
    # both still decode exactly
    mux.feed(incumbent, ems[0][64:])
    mux.feed(joiner, ems[1][16:])
    for sid, em in ((incumbent, ems[0]), (joiner, ems[1])):
        path, score = mux.finish(sid)
        ref_path, ref_score = viterbi_vanilla(hmm.log_pi, hmm.log_A, em)
        assert np.array_equal(path, np.asarray(ref_path))
        assert float(score) == float(ref_score)


# -- no-retrace -------------------------------------------------------------

def test_join_leave_churn_never_recompiles(hmm):
    """Session churn on a warm pool must not grow any jit cache (the full
    battery, including the forced-flush warm-up and positive control, runs
    under `python -m repro.analysis --retrace-only`)."""
    fns = inflight_jit_fns()
    if not callable(getattr(fns["inflight_step"], "_cache_size", None)):
        pytest.skip("jax.jit has no _cache_size() on this version")
    sched = InflightScheduler(hmm.log_pi, hmm.log_A, max_slots=3, block=8)
    warm = sched.submit()
    sched.feed(warm, _ems(hmm, [17])[0])
    sched.finish(warm)
    before = {k: f._cache_size() for k, f in fns.items()}
    for seed in range(3):
        ems = _ems(hmm, [25, 11, 19], seed=seed)
        sids = [sched.submit(max_lag=(8 if i == 1 else None))
                for i in range(3)]
        for sid, em in zip(sids, ems):
            sched.feed(sid, em)
            sched.pump()
        for sid in sids:
            sched.finish(sid)
    after = {k: f._cache_size() for k, f in fns.items()}
    assert after == before, f"churn recompiled: {before} -> {after}"


def test_slo_report_shape(hmm):
    sched = InflightScheduler(hmm.log_pi, hmm.log_A, max_slots=2, block=8)
    sid = sched.submit()
    sched.feed(sid, _ems(hmm, [20])[0])
    sched.finish(sid)
    rep = sched.slo_report()
    assert rep["block_latency_s"]["count"] == sched.stats["steps"] > 0
    assert rep["completion_s"]["p50"] >= 0
    assert rep["stats"]["finished"] == 1
    assert sched.device_state_bytes() > 0
