"""Constrained decoding: the PR-10 bit-identity and eager-infeasibility pins.

The contract under test (`core/constraints.py` docstring): a constrained
decode is **bit-identical** to the same method decoding the
`constrain_inputs`-masked inputs, for every method and every execution shape
(single sequence, ragged batch, sharded batch, streaming) — because every
consumer applies the same {0, NEG_INF} float adds to the same operands.
Exact methods are additionally pinned bitwise against the dense
`viterbi_vanilla` oracle over the masked inputs (`assoc` keeps its known
reassociation-level float divergence and is pinned to allclose + equal
paths).  Infeasible constraints raise `ValueError` eagerly — at construction
or compile — never NaN at decode time.
"""

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (
    BATCH_METHODS, METHODS, SPEC_BY_METHOD,
    BandConstraint, ConstraintSpec, FlashBSSpec, FlashSpec, FusedSpec,
    LexiconConstraint, OnlineBeamSpec, OnlineSpec, ScheduleConstraint,
    TransitionMaskConstraint, VanillaSpec, ViterbiDecoder,
    banded_state_bytes, constrain_inputs, erdos_renyi_hmm, plan,
    random_emissions, spec_from_tunables, spec_state_bytes, viterbi_decode,
    with_constraint,
)
from repro.core.constraints import (compiled_penalties, step_penalty,
                                    step_penalty_rows)
from repro.core.vanilla import viterbi_vanilla
from repro.runtime.jaxcompat import make_mesh

K, T = 12, 24
#: methods whose decode is exact (same best path/score as vanilla); `assoc`
#: is exact too but reassociates the max-plus reduction, so its *score*
#: differs from vanilla at float-rounding level even unconstrained.
EXACT_BITWISE = ("vanilla", "checkpoint", "flash", "fused", "online")


@pytest.fixture(scope="module")
def problem():
    key = jax.random.key(10)
    k1, k2 = jax.random.split(key)
    # edge_prob=1.0: dense log_A (every transition finite), the regime the
    # banded fused path's bit-identity contract requires
    hmm = erdos_renyi_hmm(k1, K, edge_prob=1.0)
    em = random_emissions(k2, T, K)
    return hmm, em


def _constraints() -> dict[str, ConstraintSpec]:
    chain = [(i, (i + 1) % K) for i in range(K)]
    loops = [(i, i) for i in range(K)]
    return {
        "band": BandConstraint(centers=tuple((3 * t) % K for t in range(T)),
                               width=3),
        "short_band": BandConstraint(centers=tuple(range(T // 2)), width=4),
        "lexicon": LexiconConstraint((((0, 1, 2), (0, 3, 2)), ((4, 5, 6),),
                                      ((7, 8),))),
        "transition": TransitionMaskConstraint(
            edges=tuple(chain + loops), init_states=(0, 1, 2)),
        "schedule": ScheduleConstraint(
            anchors=((0, (0, 1, 2, 3)), (5, (2, 3, 4)), (T - 1, (3, 4, 5)))),
    }


CONSTRAINTS = _constraints()


def _bitwise(a, b):
    pa, sa = a
    pb, sb = b
    return bool(jnp.all(jnp.asarray(pa) == jnp.asarray(pb))) \
        and float(sa) == float(sb)


# ---------------------------------------------------------------------------
# Construction / API surface
# ---------------------------------------------------------------------------

def test_constraints_hashable_and_replaceable():
    for c in CONSTRAINTS.values():
        assert hash(c) == hash(dataclasses.replace(c))
    band = CONSTRAINTS["band"]
    spec = with_constraint(FlashSpec(), band)
    assert spec.constraint == band and FlashSpec().constraint is None
    assert with_constraint(spec, None).constraint is None
    assert hash(spec) == hash(FlashSpec(constraint=band))


def test_spec_rejects_non_constraint():
    with pytest.raises(TypeError, match="ConstraintSpec"):
        VanillaSpec(constraint=42)


def test_legacy_surfaces_reject_constraint(problem):
    hmm, em = problem
    with pytest.raises(TypeError, match="constraint"):
        spec_from_tunables("vanilla",
                           {"constraint": CONSTRAINTS["band"]})
    with pytest.raises(TypeError, match="constraint"):
        viterbi_decode(em, hmm.log_pi, hmm.log_A, method="vanilla",
                       constraint=CONSTRAINTS["band"])


def test_penalties_are_tropical_identities(problem):
    for c in CONSTRAINTS.values():
        t_pen, pi_pen, s_pen = compiled_penalties(c, K, T)
        for pen in (t_pen, pi_pen, s_pen):
            if pen is not None:
                assert pen.dtype == np.float32
                assert set(np.unique(pen)) <= {np.float32(0.0),
                                               np.float32(-1.0e9)}
        # streaming rows are the same bits, same None-ness
        rows = step_penalty_rows(c, K, 0, T)
        if s_pen is None:
            assert rows is None
        else:
            np.testing.assert_array_equal(rows, s_pen)
    # beyond-horizon rows are zeros (unconstrained) for horizon constraints;
    # a lexicon's reachability schedule has no horizon and stays masked
    for cname in ("band", "short_band", "schedule"):
        tail = step_penalty_rows(CONSTRAINTS[cname], K, 10 * T, 3)
        assert not tail.any()


# ---------------------------------------------------------------------------
# Bit-identity: every method, against itself-over-masked-inputs and (exact
# methods) against the dense vanilla oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cname", sorted(CONSTRAINTS))
@pytest.mark.parametrize("method", METHODS)
def test_constrained_bit_identical_to_masked(problem, method, cname):
    hmm, em = problem
    c = CONSTRAINTS[cname]
    spec = SPEC_BY_METHOD[method]()
    masked = constrain_inputs(c, hmm.log_pi, hmm.log_A, em)
    got = with_constraint(spec, c).run(hmm.log_pi, hmm.log_A, em)
    want = spec.run(*masked)
    assert _bitwise(got, want), (method, cname)

    if method in EXACT_BITWISE:
        assert _bitwise(got, viterbi_vanilla(*masked)), (method, cname)
    elif method == "assoc":
        p_o, s_o = viterbi_vanilla(*masked)
        assert bool(jnp.all(got[0] == p_o))
        np.testing.assert_allclose(float(got[1]), float(s_o), rtol=1e-5)
    assert np.isfinite(float(got[1]))       # infeasibility never leaks as NaN


def test_fused_banded_path_runs_windowed(problem):
    """The covering band decodes via the sliding window, still bit-identical."""
    hmm, em = problem
    band = CONSTRAINTS["band"]
    got = FusedSpec(constraint=band).run(hmm.log_pi, hmm.log_A, em)
    want = viterbi_vanilla(*constrain_inputs(band, hmm.log_pi, hmm.log_A, em))
    assert _bitwise(got, want)
    # every decoded state is inside the band the window was built from
    centers = np.asarray(band.centers)[:T]
    assert (np.abs(np.asarray(got[0]) - np.clip(centers, 0, K - 1))
            <= band.width).all()


def test_masked_pallas_kernel_lane_aligned():
    """K=128 hits the Pallas masked kernel (interpret off-TPU), not the ref."""
    Kb, Tb = 128, 16
    key = jax.random.key(3)
    k1, k2 = jax.random.split(key)
    hmm = erdos_renyi_hmm(k1, Kb, edge_prob=1.0)
    em = random_emissions(k2, Tb, Kb)
    lex = LexiconConstraint((((0, 1, 2),), ((40, 41),), ((100, 101, 102),)))
    got = FusedSpec(constraint=lex).run(hmm.log_pi, hmm.log_A, em)
    want = viterbi_vanilla(*constrain_inputs(lex, hmm.log_pi, hmm.log_A, em))
    assert _bitwise(got, want)


# ---------------------------------------------------------------------------
# Batched (ragged), sharded, streaming
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cname", ("band", "lexicon", "schedule"))
@pytest.mark.parametrize("method", BATCH_METHODS)
def test_batched_ragged_bit_identical(problem, method, cname):
    hmm, em_one = problem
    c = CONSTRAINTS[cname]
    B = 4
    key = jax.random.key(17)
    em = random_emissions(key, B * T, K).reshape(B, T, K)
    lengths = jnp.asarray([T, T - 5, 7, 1])
    spec = SPEC_BY_METHOD[method]()
    dec_c = ViterbiDecoder(with_constraint(spec, c), hmm.log_pi, hmm.log_A)
    paths, scores = dec_c.decode_batch(em, lengths)
    mlp, mla, mem = constrain_inputs(c, hmm.log_pi, hmm.log_A, em)
    dec_m = ViterbiDecoder(spec, mlp, mla)
    p_want, s_want = dec_m.decode_batch(mem, lengths)
    assert bool(jnp.all(paths == p_want)), (method, cname)
    assert bool(jnp.all(scores == s_want)), (method, cname)


def test_sharded_bit_identical(problem):
    hmm, _ = problem
    c = CONSTRAINTS["lexicon"]
    B = 3                                   # does not divide the axis: pads
    key = jax.random.key(23)
    em = random_emissions(key, B * T, K).reshape(B, T, K)
    lengths = jnp.asarray([T, 13, 6])
    mesh = make_mesh((1,), ("data",))
    dec = ViterbiDecoder(FusedSpec(constraint=c), hmm.log_pi, hmm.log_A)
    p_sh, s_sh = dec.decode_sharded(em, lengths, mesh=mesh)
    p_b, s_b = dec.decode_batch(em, lengths)
    assert bool(jnp.all(p_sh == p_b)) and bool(jnp.all(s_sh == s_b))


@pytest.mark.parametrize("cname", ("band", "lexicon", "transition",
                                   "schedule"))
@pytest.mark.parametrize("spec_cls", (OnlineSpec, OnlineBeamSpec))
def test_streaming_bit_identical(problem, spec_cls, cname):
    hmm, em = problem
    c = CONSTRAINTS[cname]
    spec = (spec_cls(constraint=c) if spec_cls is OnlineSpec
            else spec_cls(beam_width=K, constraint=c))
    stream = ViterbiDecoder(spec, hmm.log_pi, hmm.log_A).make_streaming()
    for t0 in range(0, T, 7):               # ragged chunks
        stream.feed(em[t0:t0 + 7])
    _, score = stream.flush()
    base = dataclasses.replace(spec, constraint=None)
    p_want, s_want = base.run(*constrain_inputs(c, hmm.log_pi, hmm.log_A, em))
    assert bool(jnp.all(jnp.asarray(stream.path) == p_want)), cname
    assert float(score) == float(s_want), cname


# ---------------------------------------------------------------------------
# Eager infeasibility: ValueError at construction or compile, never NaN
# ---------------------------------------------------------------------------

def test_empty_anchor_raises_at_construction():
    with pytest.raises(ValueError, match="empty state set"):
        ScheduleConstraint(anchors=((0, ()),))
    with pytest.raises(ValueError, match="non-empty"):
        ScheduleConstraint(anchors=())
    with pytest.raises(ValueError, match="duplicate"):
        ScheduleConstraint(anchors=((2, (1,)), (2, (3,))))


def test_dead_end_transition_mask_raises_at_compile(problem):
    hmm, em = problem
    # 0 -> 1 is the only arc and 1 has no outgoing arcs: dead end at step 2
    dead = TransitionMaskConstraint(edges=((0, 1),), init_states=(0,))
    with pytest.raises(ValueError, match="infeasible"):
        compiled_penalties(dead, K, T)
    with pytest.raises(ValueError, match="infeasible"):
        ViterbiDecoder(FlashSpec(constraint=dead),
                       hmm.log_pi, hmm.log_A).decode(em)


def test_lexicon_without_loops_dies_after_word_end():
    # a single 1-state word with no self-loops and no word loops has no
    # outgoing arcs at all: infeasible for any T > 1
    lone = LexiconConstraint((((5,),),), self_loops=False, loop_words=False)
    with pytest.raises(ValueError, match="infeasible"):
        step_penalty(lone, K, T)
    looped = LexiconConstraint((((5,),),), self_loops=False, loop_words=True)
    assert step_penalty(looped, K, T) is not None


def test_out_of_range_states_raise_at_compile():
    with pytest.raises(ValueError, match="out of range"):
        compiled_penalties(
            ScheduleConstraint(anchors=((0, (K + 3,)),)), K, T)
    with pytest.raises(ValueError, match="out of range"):
        compiled_penalties(
            TransitionMaskConstraint(edges=((0, K),)), K, T)
    with pytest.raises(ValueError, match="out of range"):
        compiled_penalties(LexiconConstraint((((K, K + 1),),)), K, T)


# ---------------------------------------------------------------------------
# Planner: masks are costed, tight bands keep exact decoding on the ladder
# ---------------------------------------------------------------------------

def test_spec_state_bytes_charges_masks():
    lex = CONSTRAINTS["lexicon"]
    base = spec_state_bytes(VanillaSpec(), K, T)
    assert spec_state_bytes(VanillaSpec(constraint=lex), K, T) \
        == base + lex.mask_bytes(K, T)
    band = CONSTRAINTS["band"]
    assert spec_state_bytes(FusedSpec(constraint=band), K, T) \
        == banded_state_bytes(K, T, band.width)
    # a band that does not cover the horizon is charged like any mask
    short = CONSTRAINTS["short_band"]
    assert spec_state_bytes(FusedSpec(constraint=short), K, T) \
        == spec_state_bytes(FusedSpec(), K, T) + short.mask_bytes(K, T)


def test_planner_banded_rung_keeps_exact_alive():
    Kp, Tp = 256, 64
    band = BandConstraint(centers=tuple(range(Tp)), width=8)
    budget = banded_state_bytes(Kp, Tp, band.width) + 512
    constrained = plan(Kp, Tp, budget=budget, constraint=band)
    assert constrained.spec == FusedSpec(constraint=band)
    assert "banded" in constrained.why
    # the same budget under a band that does NOT cover the horizon: every
    # rung pays the T*K mask bytes, no banded rung applies, and the ladder
    # falls all the way to the floor — the covering band is what kept exact
    # decoding alive
    short = BandConstraint(centers=tuple(range(Tp // 2)), width=8)
    degraded = plan(Kp, Tp, budget=budget, constraint=short)
    assert isinstance(degraded.spec, FlashBSSpec)
    assert degraded.spec.constraint == short
    # every rung carries the constraint
    loose = plan(Kp, Tp, constraint=band)
    assert loose.spec.constraint == band


def test_planner_unconstrained_unchanged():
    assert plan(256, 64).spec == plan(256, 64, constraint=None).spec


# ---------------------------------------------------------------------------
# Randomised sweeps (always run) + hypothesis property tests (skip when the
# container lacks hypothesis)
# ---------------------------------------------------------------------------

def _random_band(rng, horizon):
    centers = tuple(int(c) for c in rng.integers(0, K, size=horizon))
    return BandConstraint(centers=centers, width=int(rng.integers(1, K)))


def _random_trie(rng):
    words, pool = [], rng.permutation(K)
    i = 0
    for _ in range(int(rng.integers(1, 4))):
        n = int(rng.integers(1, 4))
        words.append((tuple(int(s) for s in pool[i:i + n]),))
        i += n
    return LexiconConstraint(tuple(words))


@pytest.mark.parametrize("seed", range(5))
def test_random_band_and_trie_masks_bitwise(problem, seed):
    hmm, em = problem
    rng = np.random.default_rng(seed)
    for c in (_random_band(rng, T), _random_band(rng, T // 3),
              _random_trie(rng)):
        masked = constrain_inputs(c, hmm.log_pi, hmm.log_A, em)
        got = VanillaSpec(constraint=c).run(hmm.log_pi, hmm.log_A, em)
        assert _bitwise(got, viterbi_vanilla(*masked)), c
        got_f = FusedSpec(constraint=c).run(hmm.log_pi, hmm.log_A, em)
        assert _bitwise(got_f, viterbi_vanilla(*masked)), c


def test_hypothesis_band_property(problem):
    hypothesis = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")
    hmm, em = problem

    @hypothesis.settings(max_examples=20, deadline=None)
    @hypothesis.given(
        centers=st.lists(st.integers(0, K - 1), min_size=1, max_size=T),
        width=st.integers(0, K))
    def check(centers, width):
        c = BandConstraint(centers=tuple(centers), width=width)
        try:
            masked = constrain_inputs(c, hmm.log_pi, hmm.log_A, em)
        except ValueError:
            return                          # infeasible: eager raise is fine
        got = VanillaSpec(constraint=c).run(hmm.log_pi, hmm.log_A, em)
        assert _bitwise(got, viterbi_vanilla(*masked))

    check()


def test_hypothesis_trie_property(problem):
    hypothesis = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")
    hmm, em = problem

    @hypothesis.settings(max_examples=20, deadline=None)
    @hypothesis.given(st.lists(
        st.lists(st.integers(0, K - 1), min_size=1, max_size=4,
                 unique=True).map(tuple),
        min_size=1, max_size=3))
    def check(prons):
        c = LexiconConstraint(tuple((p,) for p in prons))
        masked = constrain_inputs(c, hmm.log_pi, hmm.log_A, em)
        got = VanillaSpec(constraint=c).run(hmm.log_pi, hmm.log_A, em)
        assert _bitwise(got, viterbi_vanilla(*masked))

    check()
