"""Unit tests for `repro.runtime.jaxcompat` — these run on a single device
and on any supported jax version; the probes themselves are the contract."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import AbstractMesh, Mesh, PartitionSpec as P

from repro.runtime import jaxcompat as jc


def test_probes_are_booleans():
    for flag in (jc.HAS_CHECK_VMA, jc.HAS_AXIS_TYPE,
                 jc.HAS_MAKE_MESH_AXIS_TYPES, jc.ABSTRACT_MESH_TAKES_PAIRS):
        assert isinstance(flag, bool)


def test_probes_consistent_with_installed_jax():
    # the kwarg rename and jax.shard_map promotion happened together with the
    # AxisType introduction; on 0.4.x all three must be absent
    if jc.jax_version() < (0, 5, 0):
        assert not jc.HAS_AXIS_TYPE
        assert not jc.HAS_MAKE_MESH_AXIS_TYPES
        assert jc.ABSTRACT_MESH_TAKES_PAIRS
    assert jc.HAS_AXIS_TYPE == hasattr(jax.sharding, "AxisType")


def test_jax_version_tuple():
    v = jc.jax_version()
    assert isinstance(v, tuple) and len(v) == 3
    assert all(isinstance(p, int) for p in v)
    assert v >= (0, 4, 0)


def test_make_mesh_single_device():
    mesh = jc.make_mesh((1,), ("x",))
    assert isinstance(mesh, Mesh)
    assert dict(mesh.shape) == {"x": 1}


def test_shard_map_runs_on_single_device_mesh():
    mesh = jc.make_mesh((1,), ("x",))
    f = jc.shard_map(lambda a: a * 2, mesh=mesh, in_specs=(P("x"),),
                     out_specs=P("x"))
    np.testing.assert_array_equal(np.asarray(f(jnp.arange(4.0))),
                                  [0.0, 2.0, 4.0, 6.0])


def test_shard_map_check_replication_kwarg():
    """Both values of the portable kwarg map onto the installed jax."""
    mesh = jc.make_mesh((1,), ("x",))
    x = jnp.ones((2,))
    for check in (False, True):
        f = jc.shard_map(lambda a: a + 1, mesh=mesh, in_specs=(P("x"),),
                         out_specs=P("x"), check_replication=check)
        np.testing.assert_array_equal(np.asarray(f(x)), [2.0, 2.0])


def test_abstract_mesh_bridge():
    m = jc.abstract_mesh((2, 4), ("data", "model"))
    assert isinstance(m, AbstractMesh)
    assert dict(m.shape) == {"data": 2, "model": 4}
    assert m.shape["data"] == 2 and m.shape["model"] == 4


def test_abstract_mesh_mismatched_args_raise():
    with pytest.raises(ValueError):
        jc.abstract_mesh((2, 2), ("data",))
