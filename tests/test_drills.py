"""Fault drills: scripted production events run end-to-end on 8 fake devices.

Each drill is a scenario from ``repro.launch.loadtest`` — worker death with
restart-from-checkpoint, elastic mesh shrink under load, mid-run budget
shrink through the planner ladder — and each must end with every request
delivered exactly once and every path bit-identical to the reference oracle.

These are marked ``drill`` and excluded from tier-1 (see pyproject addopts):
the subprocess forces ``--xla_force_host_platform_device_count=8`` so the
mesh-rescale drill has real shards to shrink, and that flag must never leak
into the main test process.  Run them with ``make test-drills``."""

import json
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.drill

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
from repro.launch.loadtest import (LoadConfig, drill_budget_shrink,
                                   drill_mesh_rescale, drill_worker_death)

CFG = LoadConfig(seed=11, requests=12, states=24, stream_frac=0.0,
                 lengths=(9, 21, 40, 64), buckets=(64,), max_batch=4)

out = {
    "worker_death": drill_worker_death(CFG, kill_batch=1),
    # kill_batch=0 kills before anything is checkpointed: restart must fall
    # back to the empty done-mask and replay the entire trace
    "worker_death_cold": drill_worker_death(CFG, kill_batch=0),
    "mesh_rescale": drill_mesh_rescale(CFG, from_devices=4, to_devices=2),
    "budget_shrink": drill_budget_shrink(
        LoadConfig(seed=11, requests=12, states=32, stream_frac=0.0,
                   lengths=(9, 21, 40, 64), buckets=(128,), max_batch=8)),
}
print("RESULT " + json.dumps(out, default=str))
"""


@pytest.fixture(scope="module")
def results():
    env = dict(os.environ, PYTHONPATH=_SRC)
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-4000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT ")][-1]
    return json.loads(line[len("RESULT "):])


def test_worker_death_detected_and_recovered(results):
    """Heartbeat catches the dead worker; restart-from-checkpoint loses and
    duplicates nothing; every path stays bit-identical to the oracle."""
    d = results["worker_death"]
    assert d["ok"], d
    assert d["detected_dead"] == [d["killed_worker"]]
    assert d["delivered"] == d["expected"]
    assert d["duplicates"] == 0
    assert d["oracle"]["ok"] and d["oracle"]["mismatches"] == []
    # the in-flight batch died after batch 0 was checkpointed, so recovery
    # restored a real step and resubmitted only the uncovered requests
    assert d["restored_from_step"] is not None
    assert 0 < d["resubmitted"] < d["expected"]


def test_worker_death_before_first_checkpoint(results):
    """Dying before any checkpoint exists degrades to a full replay —
    still exactly-once, still bit-identical."""
    d = results["worker_death_cold"]
    assert d["ok"], d
    assert d["restored_from_step"] is None
    assert d["resubmitted"] == d["expected"]
    assert d["delivered"] == d["expected"] and d["duplicates"] == 0


def test_mesh_rescale_bit_identical(results):
    """4->2 device shrink under load: the abstract-target plan is clean, the
    probe batch decodes bit-identically on both meshes, and the migrated
    queue drains exactly-once with the oracle green."""
    d = results["mesh_rescale"]
    assert not d.get("skipped"), d
    assert d["ok"], d
    assert d["rescale_plan_problems"] == []
    assert d["probe_bit_identical"]
    assert 0 < d["delivered_before_rescale"] < d["expected"]
    assert d["delivered"] == d["expected"] and d["duplicates"] == 0
    assert d["oracle"]["ok"]


def test_budget_shrink_engages_ladder(results):
    """Shrinking the budget mid-run re-plans to a smaller rung that fits,
    and both phases pass their own spec's oracle."""
    d = results["budget_shrink"]
    assert d["ok"], d
    assert d["downgraded"]
    assert d["under_budget"]
    assert (d["footprint_after_shrink_bytes"]
            <= d["budgets_bytes"]["small"])
    assert d["plans"]["small"]["state_bytes"] < d["plans"]["big"]["state_bytes"]
    assert d["oracle"]["big"]["ok"] and d["oracle"]["big"]["exact"]
    assert d["oracle"]["small"]["ok"]
    assert d["delivered"] == d["expected"] and d["duplicates"] == 0
