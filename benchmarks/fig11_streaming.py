"""Fig. 11 (beyond-paper): streaming decode — latency-to-first-commit and peak
live-state memory vs feed chunk size and beam width.

The offline decoders pay O(T) latency before the first state is known; the
online subsystem commits prefixes at convergence points, so the interesting
numbers are (a) wall time until the first committed state, (b) mean commit
lag in steps, and (c) the peak live window (the Šrámek bounded-memory story),
for the exact decoder across chunk sizes and the beam decoder across widths.
"""

from __future__ import annotations

import time

import numpy as np
import jax

from repro.core import erdos_renyi_hmm, random_emissions, viterbi_vanilla
from repro.core.online import OnlineBeamDecoder, OnlineViterbiDecoder
from .common import emit


def _stream(decoder, em, chunk_size: int):
    """Feed em through decoder; returns per-stream metrics."""
    T = em.shape[0]
    peak_bytes = 0
    first_commit = None
    lags = []
    t0 = time.perf_counter()
    for s in range(0, T, chunk_size):
        got = decoder.feed(em[s:s + chunk_size])
        if first_commit is None and got.shape[0]:
            first_commit = time.perf_counter() - t0
        peak_bytes = max(peak_bytes, decoder.live_state_bytes())
        lags.append(decoder.lag)
    decoder.flush()
    total = time.perf_counter() - t0
    if first_commit is None:
        first_commit = total
    return dict(first_commit_s=first_commit, total_s=total,
                peak_bytes=peak_bytes, mean_lag=float(np.mean(lags)),
                peak_lag=decoder.stats["peak_lag"],
                forced=decoder.stats["forced"])


def run(full: bool = False):
    K = 512 if full else 128
    T = 4096 if full else 1024
    key = jax.random.key(11)
    k1, k2 = jax.random.split(key)
    hmm = erdos_renyi_hmm(k1, K, edge_prob=0.253)
    em = random_emissions(k2, T, K)
    viterbi_vanilla(hmm.log_pi, hmm.log_A, em)  # warm the offline baseline jit

    for chunk_size in (16, 64, 256):
        # warm-up stream compiles the chunk shapes, measured stream is clean
        _stream(OnlineViterbiDecoder(hmm.log_pi, hmm.log_A), em, chunk_size)
        m = _stream(OnlineViterbiDecoder(hmm.log_pi, hmm.log_A), em, chunk_size)
        emit(f"fig11/exact_c{chunk_size}", m["first_commit_s"],
             f"total_us={m['total_s'] * 1e6:.1f};peak_live_bytes={m['peak_bytes']};"
             f"mean_lag={m['mean_lag']:.1f};peak_lag={m['peak_lag']}")

    for B in (32, 128):
        mk = lambda: OnlineBeamDecoder(hmm.log_pi, hmm.log_A, beam_width=B,
                                       kchunk=min(128, K))
        _stream(mk(), em, 64)
        m = _stream(mk(), em, 64)
        emit(f"fig11/beam_B{B}_c64", m["first_commit_s"],
             f"total_us={m['total_s'] * 1e6:.1f};peak_live_bytes={m['peak_bytes']};"
             f"mean_lag={m['mean_lag']:.1f};peak_lag={m['peak_lag']}")

    # bounded-lag profile: the forced-flush knob trades exactness for latency
    m = _stream(OnlineViterbiDecoder(hmm.log_pi, hmm.log_A, max_lag=64), em, 64)
    emit("fig11/exact_c64_lag64", m["first_commit_s"],
         f"total_us={m['total_s'] * 1e6:.1f};peak_live_bytes={m['peak_bytes']};"
         f"mean_lag={m['mean_lag']:.1f};forced={m['forced']}")


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
