"""Fig. 7 analogue: decoding time & live state bytes vs state-space size K and
sequence length T, with FLASH at parallelism 2/7/16."""

from __future__ import annotations

import jax

from repro.core import (erdos_renyi_hmm, random_emissions, viterbi_vanilla,
                        viterbi_checkpoint, flash_viterbi, flash_bs_viterbi)
from .common import timeit, decoder_state_bytes, emit


def run(full: bool = False):
    Ks = [64, 128, 256] + ([512, 1024] if full else [])
    Ts = [64, 128, 256] + ([512, 1024] if full else [])
    key = jax.random.key(1)

    for K in Ks:
        k1, k2, key = jax.random.split(key, 3)
        hmm = erdos_renyi_hmm(k1, K)
        em = random_emissions(k2, 256, K)
        for name, fn, mm, kw in [
            ("vanilla", viterbi_vanilla, "vanilla", {}),
            ("checkpoint", viterbi_checkpoint, "checkpoint", {}),
            ("flash_P2", lambda a, b, c: flash_viterbi(a, b, c, parallelism=2), "flash", {"P": 2}),
            ("flash_P7", lambda a, b, c: flash_viterbi(a, b, c, parallelism=7), "flash", {"P": 7}),
            ("flash_P16", lambda a, b, c: flash_viterbi(a, b, c, parallelism=16), "flash", {"P": 16}),
            ("flash_bs_P7", lambda a, b, c: flash_bs_viterbi(a, b, c, beam_width=min(128, K), parallelism=7), "flash_bs", {"P": 7, "B": min(128, K)}),
        ]:
            t = timeit(fn, hmm.log_pi, hmm.log_A, em, repeats=2)
            emit(f"fig7/K{K}/{name}", t,
                 f"state_bytes={decoder_state_bytes(mm, K, 256, **kw)}")

    for T in Ts:
        k1, k2, key = jax.random.split(key, 3)
        hmm = erdos_renyi_hmm(k1, 256)
        em = random_emissions(k2, T, 256)
        for name, fn, mm, kw in [
            ("vanilla", viterbi_vanilla, "vanilla", {}),
            ("flash_P7", lambda a, b, c: flash_viterbi(a, b, c, parallelism=7), "flash", {"P": 7}),
            ("flash_bs_P7", lambda a, b, c: flash_bs_viterbi(a, b, c, beam_width=128, parallelism=7), "flash_bs", {"P": 7, "B": 128}),
        ]:
            t = timeit(fn, hmm.log_pi, hmm.log_A, em, repeats=2)
            emit(f"fig7/T{T}/{name}", t,
                 f"state_bytes={decoder_state_bytes(mm, 256, T, **kw)}")


if __name__ == "__main__":
    run()
