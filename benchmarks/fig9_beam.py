"""Fig. 9 analogue: beam width vs time, live state bytes, and relative error
(eta = |l_opt - l| / |l_opt|) on a forced-alignment-style workload."""

from __future__ import annotations

import jax

from repro.core import (left_to_right_hmm, random_emissions, viterbi_vanilla,
                        flash_bs_viterbi, relative_error, path_score)
from .common import timeit, decoder_state_bytes, emit


def run(full: bool = False):
    K = 1024 if full else 512
    T = 256
    key = jax.random.key(3)
    k1, k2 = jax.random.split(key)
    hmm = left_to_right_hmm(k1, K, 64)
    em = random_emissions(k2, T, K)
    _, opt = viterbi_vanilla(hmm.log_pi, hmm.log_A, em)

    widths = [32, 64, 128, 256, 512] + ([1024] if full else [])
    for B in widths:
        B = min(B, K)
        t = timeit(lambda: flash_bs_viterbi(hmm.log_pi, hmm.log_A, em,
                                            beam_width=B, parallelism=7),
                   repeats=2)
        path, _ = flash_bs_viterbi(hmm.log_pi, hmm.log_A, em, beam_width=B,
                                   parallelism=7)
        ll = path_score(hmm.log_pi, hmm.log_A, em, path)
        eta = float(relative_error(opt, ll))
        emit(f"fig9/B{B}", t,
             f"state_bytes={decoder_state_bytes('flash_bs', K, T, P=7, B=B)};"
             f"rel_err={eta:.2e}")


if __name__ == "__main__":
    run()
