"""Table I analogue: overall time/memory of FLASH variants vs baselines.

Columns: decoding time for interpreted (numpy, the paper's "Py") and jitted
XLA (the paper's optimised "C") implementations, at sequential and lane-
parallel settings, plus live decoder-state bytes and the ratios the paper
reports.  Workload: forced-alignment-style left-to-right HMM (quick mode
K=512, T=256; --full matches the paper's K=3965, T=256)."""

from __future__ import annotations

import numpy as np
import jax

from repro.core import (left_to_right_hmm, random_emissions, viterbi_vanilla,
                        viterbi_checkpoint, flash_viterbi, flash_bs_viterbi,
                        beam_static_viterbi, beam_static_mp_viterbi)
from repro.core import reference as ref
from .common import timeit, timeit_np, decoder_state_bytes, emit


def run(full: bool = False):
    K = 3965 if full else 512
    T = 256
    B = 128
    key = jax.random.key(0)
    k1, k2 = jax.random.split(key)
    hmm = left_to_right_hmm(k1, K, 64)
    em = random_emissions(k2, T, K)
    em_np = np.asarray(em)
    lp_np, lA_np = np.asarray(hmm.log_pi), np.asarray(hmm.log_A)

    rows = []

    def row(name, fn, mem_method, np_fn=None, **mem_kw):
        t = timeit(fn)
        mem = decoder_state_bytes(mem_method, K, T, **mem_kw)
        t_np = timeit_np(np_fn) if np_fn else None
        rows.append((name, t, t_np, mem))
        py = f"py_ratio={t_np / t:.1f}" if t_np else ""
        emit(f"table1/{name}", t, f"state_bytes={mem};{py}")

    row("vanilla", lambda: viterbi_vanilla(hmm.log_pi, hmm.log_A, em),
        "vanilla", np_fn=lambda: ref.viterbi_numpy(lp_np, lA_np, em_np))
    row("checkpoint", lambda: viterbi_checkpoint(hmm.log_pi, hmm.log_A, em),
        "checkpoint",
        np_fn=lambda: ref.checkpoint_viterbi_numpy(lp_np, lA_np, em_np))
    row("sieve_mp(np)", lambda: ref.sieve_mp_numpy(lp_np, lA_np, em_np),
        "sieve_mp")
    for P in (1, 7, 16):
        row(f"flash_P{P}",
            lambda P=P: flash_viterbi(hmm.log_pi, hmm.log_A, em, parallelism=P),
            "flash", P=P)
    for P in (1, 7, 16):
        row(f"flash_bs_P{P}_B{B}",
            lambda P=P: flash_bs_viterbi(hmm.log_pi, hmm.log_A, em,
                                         beam_width=B, parallelism=P),
            "flash_bs", P=P, B=B)
    row(f"beam_static_B{B}",
        lambda: beam_static_viterbi(hmm.log_pi, hmm.log_A, em, B=B),
        "beam_static", B=B)
    row(f"beam_static_mp_B{B}",
        lambda: beam_static_mp_viterbi(hmm.log_pi, hmm.log_A, em, beam_width=B,
                                       parallelism=8),
        "beam_static_mp", B=B)

    # headline ratios (paper Table I style)
    d = {n: (t, m) for n, t, _, m in rows}
    van_t, van_m = d["vanilla"]
    fl_t, fl_m = d["flash_P7"]
    fb_t, fb_m = d[f"flash_bs_P7_B{B}"]
    emit("table1/flash_vs_vanilla_speed", fl_t, f"x={van_t / fl_t:.2f}")
    emit("table1/flash_vs_vanilla_mem", 0, f"x={van_m / fl_m:.1f}")
    emit("table1/flash_bs_vs_static_mem", 0,
         f"x={d[f'beam_static_B{B}'][1] / fb_m:.1f}")
    return rows


if __name__ == "__main__":
    run()
