"""Benchmark runner. Prints ``name,us_per_call,derived`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run [--full] [--quick] [--only table1,fig9]

Default mode uses reduced sizes so the whole suite finishes on one CPU core;
--full matches the paper's settings (K=3965 alignment, sweeps to 2048);
--quick runs the ~30-second CI smoke subset (kernel model + batched decode)."""

import argparse
import sys
import traceback

QUICK_SUITES = ["fig10", "fig12", "fig13"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke subset (~30 s)")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    from . import (table1_overall, fig7_scaling, fig8_density, fig9_beam,
                   fig10_kernel, fig11_streaming, fig12_batch,
                   fig13_constrained, roofline_table)
    suites = {
        "table1": table1_overall.run,
        "fig7": fig7_scaling.run,
        "fig8": fig8_density.run,
        "fig9": fig9_beam.run,
        "fig10": fig10_kernel.run,
        "fig11": fig11_streaming.run,
        "fig12": fig12_batch.run,
        "fig13": fig13_constrained.run,
        "roofline": roofline_table.run,
    }
    if args.only:
        picked = args.only.split(",")
    elif args.quick:
        picked = QUICK_SUITES
    else:
        picked = list(suites)
    print("name,us_per_call,derived")
    failed = []
    for name in picked:
        try:
            suites[name](full=args.full)
        except Exception:
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"FAILED suites: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
