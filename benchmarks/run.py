"""Benchmark runner. Prints ``name,us_per_call,derived`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only table1,fig9]

Quick mode (default) uses reduced sizes so the whole suite finishes on one
CPU core; --full matches the paper's settings (K=3965 alignment, sweeps to
2048)."""

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    from . import (table1_overall, fig7_scaling, fig8_density, fig9_beam,
                   fig10_kernel, fig11_streaming, roofline_table)
    suites = {
        "table1": table1_overall.run,
        "fig7": fig7_scaling.run,
        "fig8": fig8_density.run,
        "fig9": fig9_beam.run,
        "fig10": fig10_kernel.run,
        "fig11": fig11_streaming.run,
        "roofline": roofline_table.run,
    }
    picked = args.only.split(",") if args.only else list(suites)
    print("name,us_per_call,derived")
    failed = []
    for name in picked:
        try:
            suites[name](full=args.full)
        except Exception:
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"FAILED suites: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
