"""Render the dry-run roofline table (reads dryrun_results.jsonl)."""

from __future__ import annotations

import json
import os

from .common import emit


def run(full: bool = False, path: str = "dryrun_results.jsonl"):
    if not os.path.exists(path):
        emit("roofline/missing", 0, f"run repro.launch.dryrun first ({path})")
        return
    rows = [json.loads(l) for l in open(path)]
    for r in rows:
        if r.get("status") != "ok":
            continue
        emit(f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}",
             max(r["compute_s"], r["memory_s"], r["collective_s"]),
             f"dominant={r['dominant']};useful={r['useful_ratio']:.3f};"
             f"frac={r['roofline_fraction']:.4f}")


if __name__ == "__main__":
    run()
