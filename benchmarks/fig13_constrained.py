"""Fig. 13 (beyond-paper): constrained decoding — what a ConstraintSpec buys.

Two tables, written to ``benchmarks/out/fig13_constrained.json``:

* **band rows** — a `BandConstraint` at widths K/4, K/8, K/16 over a dense
  HMM, comparing the generic constrained path (jitted vanilla over the
  `constrain_inputs`-masked inputs — what every non-fused method runs) with
  the sliding-window banded decode (`viterbi_decode_banded`, what
  `FusedSpec(constraint=band)` runs).  The banded column must win on *both*
  wall time (Kb^2 vs K^2 work per step) and live state bytes (Kb-wide DP
  rows vs K-wide rows plus the materialised mask); every row also records an
  inline bit-identity check of the two paths/scores.

* **lexicon rows** — a `LexiconConstraint` at growing vocabulary sizes:
  decode time over the masked inputs, the compiled mask bytes the planner
  charges, and the shrunken live-state count (the quantity `plan` uses to
  keep exact decoding on the ladder).
"""

from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp

from repro.core import (BandConstraint, LexiconConstraint, banded_state_bytes,
                        constrain_inputs, random_emissions)
from repro.core.vanilla import viterbi_vanilla
from repro.kernels.ops import viterbi_decode_banded
from .common import decoder_state_bytes, emit, timeit

OUT_JSON = os.path.join(os.path.dirname(__file__), "out",
                        "fig13_constrained.json")


def _lexicon(n_words: int, states_per_word: int = 4) -> LexiconConstraint:
    """Disjoint straight-line words over states [0, n_words*states_per_word)."""
    words = tuple(
        (tuple(range(w * states_per_word, (w + 1) * states_per_word)),)
        for w in range(n_words))
    return LexiconConstraint(words)


def run(full: bool = False):
    K = 256
    T = 256 if full else 96
    key = jax.random.key(13)
    k1, k2, k3 = jax.random.split(key, 3)
    # dense HMM (every transition finite): the regime the banded window's
    # bit-identity contract asks for, and the worst case for dense masking
    log_A = jax.nn.log_softmax(jax.random.normal(k1, (K, K)), axis=1)
    log_pi = jax.nn.log_softmax(jax.random.normal(k2, (K,)))
    em = random_emissions(k3, T, K)
    # band centers: a slow sweep across the state space, like the
    # map-matching fixes (examples/map_matching.py)
    centers = tuple(int(c) for c in
                    jnp.linspace(0, K - 1, T).round().astype(int))

    dense = jax.jit(viterbi_vanilla)

    band_rows = []
    for div in (4, 8, 16):
        w = K // div
        band = BandConstraint(centers=centers, width=w)
        mlp, mla, mem = constrain_inputs(band, log_pi, log_A, em)
        banded = jax.jit(lambda lp, la, e, c=jnp.asarray(centers):
                         viterbi_decode_banded(lp, la, e, c, width=w))

        p_dense, s_dense = dense(mlp, mla, mem)
        p_band, s_band = banded(log_pi, log_A, em)
        bit = (bool(jnp.all(p_dense == p_band))
               and float(s_dense) == float(s_band))

        t_dense = timeit(dense, mlp, mla, mem, repeats=5)
        t_band = timeit(banded, log_pi, log_A, em, repeats=5)
        dense_bytes = (decoder_state_bytes("vanilla", K, T)
                       + band.mask_bytes(K, T))
        band_bytes = banded_state_bytes(K, T, w)
        emit(f"fig13/band_w{w}", t_band,
             f"dense_masked_us={t_dense * 1e6:.1f};"
             f"speedup={t_dense / t_band:.2f}x;bit_identical={bit}")
        band_rows.append(dict(
            K=K, T=T, width=w, width_frac=f"K/{div}", bit_identical=bit,
            dense_masked_s=t_dense, banded_s=t_band,
            speedup=t_dense / t_band,
            state_bytes_dense_masked=dense_bytes,
            state_bytes_banded=band_bytes))

    lex_rows = []
    for n_words in (4, 16, 64):
        lex = _lexicon(n_words)
        mlp, mla, mem = constrain_inputs(lex, log_pi, log_A, em)
        t_masked = timeit(dense, mlp, mla, mem, repeats=5)
        emit(f"fig13/lexicon_{n_words}w", t_masked,
             f"live_states={lex.live_states(K)}/{K};"
             f"mask_bytes={lex.mask_bytes(K, T)}")
        lex_rows.append(dict(
            K=K, T=T, n_words=n_words, masked_s=t_masked,
            mask_bytes=lex.mask_bytes(K, T),
            live_states=lex.live_states(K)))

    os.makedirs(os.path.dirname(OUT_JSON), exist_ok=True)
    with open(OUT_JSON, "w") as f:
        json.dump(dict(backend=jax.default_backend(),
                       interpret=jax.default_backend() != "tpu",
                       band_rows=band_rows, lexicon_rows=lex_rows), f,
                  indent=2)
    emit("fig13/json_written", 0.0, OUT_JSON)


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
