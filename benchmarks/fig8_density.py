"""Fig. 8 analogue: decoding time vs transition-graph edge probability p.
FLASH variants use the dense state-matrix formulation, so their runtime should
be flat in p (the paper's robustness claim vs token-passing baselines)."""

from __future__ import annotations

import numpy as np
import jax

from repro.core import erdos_renyi_hmm, random_emissions, flash_viterbi, \
    flash_bs_viterbi, viterbi_vanilla
from .common import timeit, emit


def run(full: bool = False):
    ps = [0.05, 0.113, 0.253, 0.57, 1.0] if not full else \
        [0.05, 0.075, 0.113, 0.169, 0.253, 0.38, 0.57, 0.85, 1.0]
    key = jax.random.key(2)
    times = {}
    for p in ps:
        k1, k2, key = jax.random.split(key, 3)
        hmm = erdos_renyi_hmm(k1, 256, edge_prob=p)
        em = random_emissions(k2, 256, 256)
        for name, fn in [
            ("vanilla", viterbi_vanilla),
            ("flash_P7", lambda a, b, c: flash_viterbi(a, b, c, parallelism=7)),
            ("flash_bs_P7", lambda a, b, c: flash_bs_viterbi(
                a, b, c, beam_width=128, parallelism=7)),
        ]:
            t = timeit(fn, hmm.log_pi, hmm.log_A, em, repeats=2)
            times.setdefault(name, []).append(t)
            emit(f"fig8/p{p}/{name}", t)
    for name, ts in times.items():
        cv = float(np.std(ts) / np.mean(ts))
        emit(f"fig8/{name}_cv_over_p", float(np.mean(ts)), f"cv={cv:.3f}")


if __name__ == "__main__":
    run()
