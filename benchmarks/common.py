"""Shared benchmark utilities: timing, memory accounting, CSV emit.

Wall-clock rows compare the interpreted numpy implementations (the paper's
"Py" column analogue) against the jitted XLA ones (the "C" column analogue) on
this host.  Memory rows are *live decoder-state bytes* from the documented
analytic formulas — the quantity the paper's Fig. 1/7/9 track — because RSS on
a JIT runtime measures the allocator, not the algorithm.
"""

from __future__ import annotations

import time

import numpy as np
import jax


def timeit(fn, *args, repeats: int = 3, warmup: int = 1) -> float:
    """Median wall seconds; blocks on jax outputs."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def timeit_np(fn, *args, repeats: int = 1) -> float:
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn(*args)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def decoder_state_bytes(method: str, K: int, T: int, P: int = 8,
                        B: int = 128) -> int:
    """Live DP-state bytes per the complexity table (paper Fig. 1).

    4-byte scores + 4-byte indices; FLASH tracks (OptProb, PreState-equivalent,
    MidState/DivState); beams track (score, state, mid) per slot.
    """
    if method == "vanilla":
        return K * T * 4 + K * 8                 # psi table + delta
    if method == "checkpoint":
        c = int(np.ceil(np.sqrt(T)))
        return K * c * 4 + K * c * 4 + K * 8     # checkpoints + segment psis
    if method in ("sieve", "sieve_mp"):
        return K * 12                            # delta + mid + entry vector
    if method == "flash":
        return P * K * 12 + (P - 1) * K * 4      # P lanes + DivState
    if method == "flash_bs":
        return P * B * 12 + (P - 1) * B * 4
    if method == "beam_static":
        return K * 4 + T * B * 8                 # full-K transient + survivors
    if method == "beam_static_mp":
        return K * 4 + P * B * 12                # full-K transient per step
    if method == "assoc":
        return T * K * K * 4
    raise ValueError(method)


def emit(name: str, seconds: float, derived: str = ""):
    """CSV row: name,us_per_call,derived."""
    print(f"{name},{seconds * 1e6:.1f},{derived}")


__all__ = ["timeit", "timeit_np", "decoder_state_bytes", "emit"]
