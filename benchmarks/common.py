"""Shared benchmark utilities: timing, memory accounting, CSV emit.

Wall-clock rows compare the interpreted numpy implementations (the paper's
"Py" column analogue) against the jitted XLA ones (the "C" column analogue) on
this host.  Memory rows are *live decoder-state bytes* — the quantity the
paper's Fig. 1/7/9 track — because RSS on a JIT runtime measures the
allocator, not the algorithm.  The analytic formulas live in
`repro.core.planner` (the planner's cost model is the single source of
truth); `decoder_state_bytes` is re-exported here for the benchmark suites.
"""

from __future__ import annotations

import time

import numpy as np
import jax

from repro.core.planner import decoder_state_bytes


def timeit(fn, *args, repeats: int = 3, warmup: int = 1) -> float:
    """Median wall seconds; blocks on jax outputs."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def timeit_np(fn, *args, repeats: int = 1) -> float:
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn(*args)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def emit(name: str, seconds: float, derived: str = ""):
    """CSV row: name,us_per_call,derived."""
    print(f"{name},{seconds * 1e6:.1f},{derived}")


def flashprove_peak_bytes(method: str, K: int, T: int,
                          batch: int | None = None, **fields) -> int:
    """flashprove's IR-derived peak DP-state bytes for `method` at (K, T).

    Traces the same jit body the decoder would run (the `decode_batch` body
    when `batch` is given) and takes the liveness walk's stateful peak —
    the *predicted* column the emitted JSON carries next to the planner's
    modeled `decoder_state_bytes` so the perf trajectory can plot
    predicted-vs-actual.  The analysis layer is imported lazily so plain
    timing runs don't pay for a trace.
    """
    from repro.analysis.jaxpr_check import (batch_entry_jaxpr,
                                            dp_state_bytes, entry_jaxpr)
    from repro.core.spec import SPEC_BY_METHOD

    spec = SPEC_BY_METHOD[method](**fields)
    closed = (entry_jaxpr(spec, K, T) if batch is None
              else batch_entry_jaxpr(spec, K, T, batch))
    return dp_state_bytes(closed)


__all__ = ["timeit", "timeit_np", "decoder_state_bytes", "emit",
           "flashprove_peak_bytes"]
