"""Fig. 10 / Table II analogue: the hardware-acceleration story on TPU.

No TPU is attached, so this reports (a) interpret-mode correctness timing is
meaningless and therefore *excluded*; (b) the structural metrics that
determine the speedup on hardware: HBM round-trips per DP step removed by the
fused kernel, VMEM residency, and the modeled v5e step time for the XLA scan
path vs the fused Pallas path (both from the documented bandwidth/flops
model).  The paper's Table II resource table maps to the kernel's VMEM
budget accounting."""

from __future__ import annotations


from .common import emit

HBM_BW = 819e9
PEAK = 197e12 / 2   # tropical ops run on the VPU at ~fp32 vector rate proxy


def run(full: bool = False):
    T = 512
    for K in (128, 256, 512, 1024, 2048):
        a_bytes = K * K * 4
        # XLA scan path: per step, read A + delta + em, write delta + psi
        xla_bytes_step = a_bytes + 3 * K * 4 + K * 4
        # fused kernel: A resident in VMEM; per step stream em in, psi out
        pallas_bytes_step = K * 4 + K * 4
        flops_step = 2 * K * K
        t_xla = max(xla_bytes_step / HBM_BW, flops_step / PEAK) * T
        t_pal = max(pallas_bytes_step / HBM_BW, flops_step / PEAK) * T
        fits = "vmem_ok" if a_bytes <= 12 * 2**20 else "vmem_spill"
        emit(f"fig10/K{K}/xla_scan_model", t_xla,
             f"bytes_per_step={xla_bytes_step}")
        emit(f"fig10/K{K}/pallas_fused_model", t_pal,
             f"bytes_per_step={pallas_bytes_step};{fits};"
             f"speedup={t_xla / t_pal:.1f}x")


if __name__ == "__main__":
    run()
