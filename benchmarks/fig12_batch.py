"""Fig. 12 (beyond-paper): batched decode throughput — one batch-grid fused
kernel launch vs a per-sequence decode loop.

The serving scheduler's whole reason to batch is that one launch amortises
dispatch overhead and the transition-matrix load across the bucket (the GPU
Viterbi literature's batch-axis parallelism).  This benchmark measures exactly
that trade on this host: `viterbi_decode_batch(method="fused")` (grid (B, T/bt),
log_A resident) against a Python loop of jitted single-sequence
`viterbi_decode_fused` calls over the same emissions.  Off-TPU the kernel runs
in interpret mode, so absolute numbers are conservative; the dispatch-
amortisation effect is what the speedup column tracks.  Results are also
written to ``benchmarks/out/fig12_batch.json``.
"""

from __future__ import annotations

import json
import os

import jax

from repro.core import erdos_renyi_hmm, random_emissions
from repro.core.batch import viterbi_decode_batch
from repro.kernels.ops import viterbi_decode_fused
from .common import decoder_state_bytes, emit, flashprove_peak_bytes, timeit

OUT_JSON = os.path.join(os.path.dirname(__file__), "out", "fig12_batch.json")


def run(full: bool = False):
    K = 128
    T = 512 if full else 32
    batch_sizes = (1, 8, 16, 32) if full else (1, 8, 16)
    key = jax.random.key(12)
    k1, k2 = jax.random.split(key)
    hmm = erdos_renyi_hmm(k1, K, edge_prob=0.3)
    em_all = random_emissions(k2, max(batch_sizes) * T, K).reshape(
        max(batch_sizes), T, K)

    batched = jax.jit(lambda e: viterbi_decode_batch(
        e, hmm.log_pi, hmm.log_A, method="fused"))
    per_seq = jax.jit(lambda e: viterbi_decode_fused(
        hmm.log_pi, hmm.log_A, e))

    rows = []
    for B in batch_sizes:
        em = em_all[:B]

        def loop_fn(ems):
            return [per_seq(ems[i]) for i in range(B)]

        t_batch = timeit(batched, em, repeats=5)
        t_loop = timeit(loop_fn, em, repeats=5)
        speedup = t_loop / t_batch
        # memory columns: the planner's modeled per-sequence state bytes
        # (x B) next to flashprove's IR-derived peak over the actual batch
        # jaxpr — predicted-vs-modeled for the perf trajectory.
        model_bytes = decoder_state_bytes("fused", K, T) * B
        predicted_bytes = flashprove_peak_bytes("fused", K, T, batch=B)
        emit(f"fig12/fused_batch_B{B}", t_batch,
             f"loop_us={t_loop * 1e6:.1f};speedup={speedup:.2f}x")
        rows.append(dict(B=B, T=T, K=K, batch_s=t_batch, loop_s=t_loop,
                         speedup=speedup, state_bytes_model=model_bytes,
                         state_bytes_flashprove=predicted_bytes))

    os.makedirs(os.path.dirname(OUT_JSON), exist_ok=True)
    with open(OUT_JSON, "w") as f:
        json.dump(dict(backend=jax.default_backend(),
                       interpret=jax.default_backend() != "tpu",
                       rows=rows), f, indent=2)
    emit("fig12/json_written", 0.0, OUT_JSON)


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
