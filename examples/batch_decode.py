"""Batched decoding: one launch for a whole ragged request bucket.

    PYTHONPATH=src python examples/batch_decode.py

Builds a shared HMM, a batch of emission sequences with *different* true
lengths, and decodes them three ways:

  1. `viterbi_decode_batch(method="fused")` — one batch-grid kernel launch,
     pad frames masked as tropical-identity steps;
  2. a Python loop of single-sequence `viterbi_decode` calls (the semantics
     the batch must reproduce bit-for-bit);
  3. through the serving `BatchScheduler`, which buckets, pads, and passes
     `lengths` so results stay exact.
"""

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (erdos_renyi_hmm, random_emissions, viterbi_decode,
                        viterbi_decode_batch, FusedSpec)
from repro.serving.alignment import make_alignment_head
from repro.serving.scheduler import BatchScheduler

K, TMAX, B = 128, 96, 8

key = jax.random.key(0)
k_hmm, k_em = jax.random.split(key)
hmm = erdos_renyi_hmm(k_hmm, K, edge_prob=0.3)
em = random_emissions(k_em, B * TMAX, K).reshape(B, TMAX, K)
rng = np.random.default_rng(0)
lengths = np.sort(rng.integers(1, TMAX + 1, B))[::-1].copy()
lengths[0] = TMAX
print(f"batch of {B} sequences, K={K}, ragged lengths={lengths.tolist()}\n")

# 1. one batched launch (ragged lengths masked as tropical-identity steps)
paths, scores = viterbi_decode_batch(em, hmm.log_pi, hmm.log_A,
                                     jnp.asarray(lengths), method="fused")
jax.block_until_ready(paths)
t0 = time.perf_counter()
paths, scores = viterbi_decode_batch(em, hmm.log_pi, hmm.log_A,
                                     jnp.asarray(lengths), method="fused")
jax.block_until_ready(paths)
t_batch = time.perf_counter() - t0

# 2. the per-sequence loop it must reproduce bit-for-bit (warmed first, so
# the timing compares dispatch + compute, not per-length jit compiles)
def run_loop():
    return [viterbi_decode(em[i, :int(L)], hmm.log_pi, hmm.log_A,
                           method="fused") for i, L in enumerate(lengths)]

looped = run_loop()
jax.block_until_ready(looped)
t0 = time.perf_counter()
looped = run_loop()
jax.block_until_ready(looped)
t_loop = time.perf_counter() - t0

ok = all(
    np.array_equal(np.asarray(paths[i, :int(L)]), np.asarray(looped[i][0]))
    and np.isclose(float(scores[i]), float(looped[i][1]), rtol=1e-6)
    for i, L in enumerate(lengths))
print(f"batched == looped per sequence: {ok}")
print(f"batched launch: {t_batch * 1e3:.2f} ms   "
      f"loop of {B}: {t_loop * 1e3:.2f} ms "
      f"(both warmed; the loop also pays one jit compile per distinct length "
      f"on first contact, which buckets avoid entirely)\n")

# 3. the serving path: scheduler buckets + pads, decoder masks the pads
head = make_alignment_head(hmm.log_pi, hmm.log_A, FusedSpec())
sched = BatchScheduler(head, max_batch=B, buckets=(TMAX,))
reqs = [sched.submit(np.asarray(em[i, :int(L)])) for i, L in enumerate(lengths)]
done = sched.drain()
ok = all(
    np.array_equal(r.result[0], np.asarray(paths[i, :int(lengths[i])]))
    and np.isclose(r.result[1], float(scores[i]), rtol=1e-6)
    for i, r in enumerate(done))
print(f"scheduler results == batched decode: {ok}")
print(f"scheduler stats: {sched.stats['batches']} batch(es), "
      f"mean pad frac {np.mean(sched.stats['padded_frac']):.2f} "
      f"-- padding costs throughput only, never correctness")
