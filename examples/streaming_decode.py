"""Streaming decode: states become final while the sequence is still arriving.

    PYTHONPATH=src python examples/streaming_decode.py

Simulates a live feed (emission chunks arriving over time) against a
StreamSession, printing each committed prefix as it becomes final, then
verifies the assembled path is bit-identical to the offline decode.  The
second half shows the serving shape: a StreamMux carrying two concurrent
sessions with different latency/memory profiles (exact vs narrow beam).
"""

import numpy as np
import jax

from repro.core import erdos_renyi_hmm, sample_observations, viterbi_vanilla
from repro.serving import StreamConfig, StreamSession, StreamMux

K, T, CHUNK = 64, 512, 32

key = jax.random.key(0)
k_hmm, k_obs = jax.random.split(key)
hmm = erdos_renyi_hmm(k_hmm, K, num_obs=50, edge_prob=0.253)
_, obs = sample_observations(k_obs, hmm, T)
em = np.asarray(hmm.emissions(obs))

print(f"live feed: K={K}, T={T}, {CHUNK}-frame chunks\n")
sess = StreamSession(hmm.log_pi, hmm.log_A, StreamConfig(), block=CHUNK)
for start in range(0, T, CHUNK):
    committed = sess.feed(em[start:start + CHUNK])
    n = sess.decoder.n_committed
    bar = "#" * (40 * n // T)
    print(f"  t={start + CHUNK:4d}  +{committed.shape[0]:3d} states final "
          f"(lag {sess.lag:3d}, live {sess.live_state_bytes():6d} B)  |{bar}")
path, score = sess.finish()

ref_path, ref_score = viterbi_vanilla(hmm.log_pi, hmm.log_A, em)
assert np.array_equal(path, np.asarray(ref_path))
first = (f"first commit after {sess.first_commit_s * 1e3:.1f} ms"
         if sess.first_commit_s is not None else "no commit before finish()")
print(f"\nassembled path == offline decode (score {score:.2f}); {first}\n")

print("two concurrent sessions, one mux (exact vs B=16 beam):")
mux = StreamMux(hmm.log_pi, hmm.log_A,
                StreamConfig(method="online_beam", beam_width=16, kchunk=64),
                blocks=(CHUNK,))
exact = StreamSession(hmm.log_pi, hmm.log_A, StreamConfig(), block=CHUNK)
sid = mux.open(block=CHUNK)
for start in range(0, T, CHUNK):
    exact.feed(em[start:start + CHUNK])
    mux.feed(sid, em[start:start + CHUNK])
p1, s1 = exact.finish()
p2, s2 = mux.finish(sid)
agree = float(np.mean(p1 == p2))
print(f"  exact   : score {s1:9.2f}, live state O(W*K)")
print(f"  beam 16 : score {s2:9.2f}, live state O(W*B) — "
      f"{100 * agree:.1f}% of states agree with exact")
