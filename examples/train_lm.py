"""End-to-end training example: a ~100M-param LM for a few hundred steps.

    PYTHONPATH=src python examples/train_lm.py            # CPU-sized default
    PYTHONPATH=src python examples/train_lm.py --m100     # the full 100M run

Demonstrates the production loop: sharded train_step, async checkpoints,
resume, loss goes down.  (The 100M configuration is the same code path; on
this 1-core container it is hours, so the default is a reduced model.)
"""

import argparse

from repro.launch.train import main as train_main

ap = argparse.ArgumentParser()
ap.add_argument("--m100", action="store_true", help="full ~100M-param run")
ap.add_argument("--steps", type=int, default=None)
args = ap.parse_args()

if args.m100:
    # ~100M params: xlstm-350m config cut to 8 layers (d=1024, vocab 50304)
    steps = args.steps or 300
    train_main(["--arch", "xlstm_350m", "--steps", str(steps),
                "--batch", "8", "--seq", "256", "--lr", "3e-4",
                "--ckpt-dir", "/tmp/repro_lm100", "--ckpt-every", "50"])
else:
    steps = args.steps or 120
    train_main(["--arch", "tinyllama-1.1b", "--smoke", "--steps", str(steps),
                "--batch", "8", "--seq", "128", "--lr", "5e-3",
                "--ckpt-dir", "/tmp/repro_lm_smoke", "--ckpt-every", "40"])
