"""Quickstart: FLASH Viterbi as a drop-in decoding operator.

    PYTHONPATH=src python examples/quickstart.py

Builds a random Erdos-Renyi HMM (the paper's synthetic workload), decodes one
observation sequence with every method in the family via typed specs and one
`ViterbiDecoder` per spec, and shows the paper's adaptivity story: the same
operator tuned for latency (high P), memory (P=1 / narrow beam), or
exactness — including letting the planner pick the spec from a byte budget.
"""

import time

import jax

from repro.core import (erdos_renyi_hmm, sample_observations, path_score,
                        relative_error, spec_state_bytes, ViterbiDecoder,
                        VanillaSpec, CheckpointSpec, FlashSpec, FlashBSSpec,
                        BeamStaticSpec, plan, ResourceBudget)

K, T = 512, 512  # the paper's default setting (Sec. VII-A)

key = jax.random.key(0)
k_hmm, k_obs = jax.random.split(key)
hmm = erdos_renyi_hmm(k_hmm, K, num_obs=50, edge_prob=0.253)
states, obs = sample_observations(k_obs, hmm, T)
em = hmm.emissions(obs)

print(f"HMM: K={K} states, T={T} steps, p=0.253 (paper defaults)\n")
print(f"{'spec':34s} {'time(ms)':>9s} {'state bytes':>12s} "
      f"{'score':>12s} {'rel.err':>9s}")

_, opt_score = ViterbiDecoder(VanillaSpec(), hmm.log_pi, hmm.log_A).decode(em)

for spec in [
    VanillaSpec(),
    CheckpointSpec(),
    FlashSpec(parallelism=1),
    FlashSpec(parallelism=7),
    FlashSpec(parallelism=16),
    FlashBSSpec(parallelism=7, beam_width=128),
    FlashBSSpec(parallelism=7, beam_width=32),
    BeamStaticSpec(beam_width=128),
]:
    dec = ViterbiDecoder(spec, hmm.log_pi, hmm.log_A)
    path, score = dec.decode(em)
    jax.block_until_ready(path)
    t0 = time.perf_counter()
    path, score = dec.decode(em)
    jax.block_until_ready(path)
    dt = (time.perf_counter() - t0) * 1e3
    ll = path_score(hmm.log_pi, hmm.log_A, em, path)
    err = float(relative_error(opt_score, ll))
    fields = ", ".join(f"{k[0].upper()}={v}" for k, v in (
        ("parallelism", getattr(spec, "parallelism", None)),
        ("beam_width", getattr(spec, "beam_width", None))) if v is not None)
    name = type(spec).__name__ + (f"({fields})" if fields else "()")
    mem = spec_state_bytes(spec, K, T)
    print(f"{name:34s} {dt:9.2f} {mem:12,d} {float(score):12.2f} {err:9.2e}")

print("\nSame operator, three deployment profiles (the paper's Fig. 1):")
print("  latency-optimal : FlashSpec(parallelism=16)      (time/P, memory O(PK))")
print("  memory-optimal  : FlashBSSpec(P=1, beam_width=32) (memory O(B), decoupled from K)")
print("  exact           : FlashSpec(parallelism=7)        (optimal path, O(PK))")

print("\nOr let the planner pick from a budget (Sec. V-C-3 ladder):")
for kb in (512, 64, 4):
    p = plan(K, T, ResourceBudget(memory_bytes=kb * 1024))
    print(f"  {kb:4d} KiB -> {p.why}")
