"""Quickstart: FLASH Viterbi as a drop-in decoding operator.

    PYTHONPATH=src python examples/quickstart.py

Builds a random Erdos-Renyi HMM (the paper's synthetic workload), decodes one
observation sequence with every method in the family, and shows the paper's
adaptivity story: the same operator tuned for latency (high P), memory
(P=1 / narrow beam), or exactness.
"""

import sys
import os
_here = os.path.dirname(os.path.abspath(__file__))
sys.path.insert(0, os.path.join(_here, "..", "src"))
sys.path.insert(0, os.path.join(_here, ".."))

import time

import jax
import jax.numpy as jnp

from repro.core import (erdos_renyi_hmm, sample_observations, viterbi_decode,
                        viterbi_decode_hmm, path_score, relative_error)
from benchmarks.common import decoder_state_bytes

K, T = 512, 512  # the paper's default setting (Sec. VII-A)

key = jax.random.key(0)
k_hmm, k_obs = jax.random.split(key)
hmm = erdos_renyi_hmm(k_hmm, K, num_obs=50, edge_prob=0.253)
states, obs = sample_observations(k_obs, hmm, T)
em = hmm.emissions(obs)

print(f"HMM: K={K} states, T={T} steps, p=0.253 (paper defaults)\n")
print(f"{'method':24s} {'time(ms)':>9s} {'state bytes':>12s} "
      f"{'score':>12s} {'rel.err':>9s}")

_, opt_score = viterbi_decode(em, hmm.log_pi, hmm.log_A, method="vanilla")

for method, kw, mem_kw in [
    ("vanilla", {}, {}),
    ("checkpoint", {}, {}),
    ("flash", {"parallelism": 1}, {"P": 1}),
    ("flash", {"parallelism": 7}, {"P": 7}),
    ("flash", {"parallelism": 16}, {"P": 16}),
    ("flash_bs", {"parallelism": 7, "beam_width": 128}, {"P": 7, "B": 128}),
    ("flash_bs", {"parallelism": 7, "beam_width": 32}, {"P": 7, "B": 32}),
    ("beam_static", {"beam_width": 128}, {"B": 128}),
]:
    fn = lambda: viterbi_decode(em, hmm.log_pi, hmm.log_A, method=method, **kw)
    path, score = fn()
    jax.block_until_ready(path)
    t0 = time.perf_counter()
    path, score = fn()
    jax.block_until_ready(path)
    dt = (time.perf_counter() - t0) * 1e3
    ll = path_score(hmm.log_pi, hmm.log_A, em, path)
    err = float(relative_error(opt_score, ll))
    name = method + (f"(P={kw.get('parallelism')})" if "parallelism" in kw else "") \
        + (f"(B={kw['beam_width']})" if "beam_width" in kw else "")
    mem = decoder_state_bytes(
        {"beam_static": "beam_static"}.get(method, method), K, T, **mem_kw)
    print(f"{name:24s} {dt:9.2f} {mem:12,d} {float(score):12.2f} {err:9.2e}")

print("\nSame operator, three deployment profiles (the paper's Fig. 1):")
print("  latency-optimal : flash     P=16           (time/P, memory O(PK))")
print("  memory-optimal  : flash_bs  P=1,  B=32     (memory O(B), decoupled from K)")
print("  exact           : flash     P=7            (optimal path, O(PK))")
