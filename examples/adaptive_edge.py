"""The paper's adaptivity story as an executable policy (Fig. 1).

Given a device memory budget and a latency target, pick (method, P, B) for a
decoding workload, then run it.  This is the "resource-adaptive operator"
contribution: one binary, tuned by two integers, covering the whole
time-space trade-off curve.

    PYTHONPATH=src python examples/adaptive_edge.py --budget-kb 64
    PYTHONPATH=src python examples/adaptive_edge.py --budget-kb 8 --seq 2048
"""

import sys
import os
_here = os.path.dirname(os.path.abspath(__file__))
sys.path.insert(0, os.path.join(_here, "..", "src"))
sys.path.insert(0, os.path.join(_here, ".."))

import argparse
import time

import jax

from repro.core import erdos_renyi_hmm, random_emissions, viterbi_decode, \
    path_score, relative_error
from benchmarks.common import decoder_state_bytes


def choose_config(K: int, T: int, budget_bytes: int):
    """Paper Sec. V-C-3: prefer exact+parallel; degrade P, then beam width."""
    for P in (16, 8, 4, 2, 1):
        if decoder_state_bytes("flash", K, T, P=P) <= budget_bytes:
            return ("flash", {"parallelism": P}), f"exact, P={P}"
    for B in (256, 128, 64, 32):
        for P in (8, 4, 1):
            if decoder_state_bytes("flash_bs", K, T, P=P, B=B) <= budget_bytes:
                return ("flash_bs", {"parallelism": P, "beam_width": B}), \
                    f"beam, P={P}, B={B}"
    return ("flash_bs", {"parallelism": 1, "beam_width": 16}), "floor: P=1,B=16"


ap = argparse.ArgumentParser()
ap.add_argument("--budget-kb", type=float, default=64)
ap.add_argument("--states", type=int, default=512)
ap.add_argument("--seq", type=int, default=512)
args = ap.parse_args()

K, T = args.states, args.seq
budget = int(args.budget_kb * 1024)
(method, kw), why = choose_config(K, T, budget)
print(f"budget={args.budget_kb:.0f}KiB K={K} T={T} -> {method} {kw}  ({why})")

key = jax.random.key(0)
k1, k2 = jax.random.split(key)
hmm = erdos_renyi_hmm(k1, K)
em = random_emissions(k2, T, K)

path, score = viterbi_decode(em, hmm.log_pi, hmm.log_A, method=method, **kw)
jax.block_until_ready(path)
t0 = time.perf_counter()
path, score = viterbi_decode(em, hmm.log_pi, hmm.log_A, method=method, **kw)
jax.block_until_ready(path)
dt = (time.perf_counter() - t0) * 1e3

_, opt = viterbi_decode(em, hmm.log_pi, hmm.log_A, method="vanilla")
ll = path_score(hmm.log_pi, hmm.log_A, em, path)
state = decoder_state_bytes(method, K, T, P=kw.get("parallelism", 8),
                            B=kw.get("beam_width", 128))
print(f"decoded in {dt:.1f}ms, state={state:,}B "
      f"(budget {budget:,}B), rel.err={float(relative_error(opt, ll)):.2e}")
