"""The paper's adaptivity story as an executable policy (Fig. 1).

Given a device memory budget, `repro.core.planner.plan` picks the decode spec
— the paper's Sec. V-C-3 degradation ladder: exact+parallel, then shrink P,
then the dynamic beam, then the floor — and a `ViterbiDecoder` runs it.  This
is the "resource-adaptive operator" contribution: one binary, tuned by two
integers, covering the whole time-space trade-off curve.

    PYTHONPATH=src python examples/adaptive_edge.py --budget-kb 64
    PYTHONPATH=src python examples/adaptive_edge.py --budget-kb 8 --seq 2048
"""

import argparse
import time

import jax

from repro.core import (erdos_renyi_hmm, random_emissions, path_score,
                        relative_error, plan, ResourceBudget, ViterbiDecoder,
                        VanillaSpec, spec_state_bytes)

ap = argparse.ArgumentParser()
ap.add_argument("--budget-kb", type=float, default=64)
ap.add_argument("--states", type=int, default=512)
ap.add_argument("--seq", type=int, default=512)
args = ap.parse_args()

K, T = args.states, args.seq
budget = ResourceBudget(memory_bytes=int(args.budget_kb * 1024))
decode_plan = plan(K, T, budget)
print(f"budget={args.budget_kb:.0f}KiB K={K} T={T} -> {decode_plan.spec}")
print(f"  why: {decode_plan.why}")

key = jax.random.key(0)
k1, k2 = jax.random.split(key)
hmm = erdos_renyi_hmm(k1, K)
em = random_emissions(k2, T, K)

dec = ViterbiDecoder(decode_plan.spec, hmm.log_pi, hmm.log_A)
path, score = dec.decode(em)
jax.block_until_ready(path)
t0 = time.perf_counter()
path, score = dec.decode(em)
jax.block_until_ready(path)
dt = (time.perf_counter() - t0) * 1e3

_, opt = ViterbiDecoder(VanillaSpec(), hmm.log_pi, hmm.log_A).decode(em)
ll = path_score(hmm.log_pi, hmm.log_A, em, path)
state = spec_state_bytes(decode_plan.spec, K, T)
print(f"decoded in {dt:.1f}ms, state={state:,}B "
      f"(budget {budget.memory_bytes:,}B), "
      f"rel.err={float(relative_error(opt, ll)):.2e}")
