"""End-to-end serving driver (the paper's kind of workload): batched
forced-alignment requests against a hubert-style encoder + FLASH-BS head.

    PYTHONPATH=src python examples/forced_alignment_serving.py
"""

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.models import build_model
from repro.core import left_to_right_hmm
from repro.serving.scheduler import BatchScheduler

# 1. encoder (reduced hubert on CPU; the full config runs on the pod)
arch = get_arch("hubert_xlarge")
cfg = arch.SMOKE
model = build_model(cfg)
key = jax.random.key(0)
params = model.init(key)
NUM_CLASSES = cfg.vocab

# 2. alignment HMM over the transcription states (left-to-right)
hmm = left_to_right_hmm(jax.random.key(1), 64, NUM_CLASSES)

# 3. one jitted serve step: encoder -> emissions -> FLASH-BS alignment.
# `lengths` masks the bucket's pad frames as tropical-identity steps, so each
# request decodes exactly as if it had been served alone.
from repro.core import viterbi_decode_batch

@jax.jit
def serve(frames, lengths):              # (B, T, d), (B,)
    logits, _ = model.prefill(params, {"embeds": frames})
    em = jax.nn.log_softmax(logits, axis=-1)
    # map class posteriors onto HMM states (states index classes mod C)
    state_to_class = jnp.arange(64) % NUM_CLASSES
    em_states = em[..., state_to_class]  # (B, T, K_states)
    return viterbi_decode_batch(em_states, hmm.log_pi, hmm.log_A, lengths,
                                method="flash_bs", beam_width=32,
                                parallelism=4, lanes=None)

sched = BatchScheduler(
    lambda b, lens: serve(jnp.asarray(b, cfg.dtype), jnp.asarray(lens)),
    max_batch=4, buckets=(64,))

rng = np.random.default_rng(0)
for _ in range(12):
    T = int(rng.integers(40, 64))
    sched.submit(rng.standard_normal((T, cfg.d_model)).astype(np.float32))

t0 = time.time()
done = sched.drain()
wall = time.time() - t0
print(f"served {len(done)} alignment requests in {wall:.2f}s "
      f"({len(done)/wall:.1f} req/s) in {sched.stats['batches']} batches")
for r in done[:3]:
    path, score = r.result
    print(f"  req {r.rid}: frames={len(r.payload)} "
          f"alignment[0:12]={path[:12].tolist()} score={score:.1f}")
print("alignment paths are monotone:",
      all(np.all(np.diff(r.result[0]) >= 0) for r in done))
