"""Map matching under a BandConstraint: spatial reachability as a constraint.

    PYTHONPATH=src python examples/map_matching.py

A vehicle random-walks on a G x G road grid (K = G^2 cells).  Noisy GPS fixes
arrive each step; map matching is Viterbi over the grid HMM with emissions
``-||obs_t - cell_k||^2 / (2 sigma^2)``.  The GPS fix itself bounds where the
vehicle can be, so decoding only ever needs the states within a few cells of
each fix — exactly a `BandConstraint` over per-step centers.

Three execution shapes, each checked bit-for-bit against the dense oracle
(`viterbi_vanilla` over the `constrain_inputs`-masked inputs):

  1. single trajectory through `FusedSpec(constraint=band)` — the band covers
     the horizon, so this runs the sliding-window banded decode that never
     materialises K-wide DP rows;
  2. a ragged batch of B sensors observing the same vehicle (one shared
     consensus band), through `ViterbiDecoder.decode_batch`;
  3. streaming: `OnlineSpec(constraint=band)` fed in chunks, committing
     matches at convergence points.
"""

import sys

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (BandConstraint, FusedSpec, OnlineSpec, ViterbiDecoder,
                        banded_state_bytes, constrain_inputs,
                        decoder_state_bytes)
from repro.core.vanilla import viterbi_vanilla

G = 16                       # grid side -> K = 256 road cells
K = G * G
T = 64                       # fixes per trajectory
B = 4                        # sensors observing the same vehicle
SIGMA = 0.45                 # GPS noise, in cell units
WIDTH = 3 * G                # band half-width in flattened-index units:
                             # +/- 3 grid rows around each fix
rng = np.random.default_rng(7)

# -- the road-grid HMM: movement cost decays with squared cell distance ------
pos = np.stack(np.meshgrid(np.arange(G), np.arange(G), indexing="ij"),
               -1).reshape(K, 2).astype(np.float32)
d2 = ((pos[:, None, :] - pos[None, :, :]) ** 2).sum(-1)
log_A = jax.nn.log_softmax(jnp.asarray(-0.7 * d2), axis=1)   # dense: every
log_pi = jax.nn.log_softmax(jnp.zeros((K,)))                 # move is finite

# -- trajectory, noisy fixes, emissions --------------------------------------
steps = rng.integers(-1, 2, size=(T, 2))
truth_xy = np.clip(np.cumsum(np.vstack([[[G // 2, G // 2]], steps[1:]]), 0),
                   0, G - 1)
truth = (truth_xy[:, 0] * G + truth_xy[:, 1]).astype(np.int64)
obs = truth_xy[None] + rng.normal(0, SIGMA, size=(B, T, 2))  # B sensors
em = jnp.asarray(
    -((obs[:, :, None, :] - pos[None, None]) ** 2).sum(-1) / (2 * SIGMA**2),
    jnp.float32)

# consensus centers: nearest cell to the sensors' mean fix, shared by every
# execution shape below (a BandConstraint is one schedule, batch-wide)
cxy = np.clip(np.round(obs.mean(0)), 0, G - 1)
centers = tuple(int(x * G + y) for x, y in cxy)
band = BandConstraint(centers=centers, width=WIDTH)

def oracle(e):
    return viterbi_vanilla(*constrain_inputs(band, log_pi, log_A, e))

ok = True

# 1. single trajectory: banded fused decode (window Kb = 2*WIDTH + 1 wide)
path1, score1 = ViterbiDecoder(FusedSpec(constraint=band),
                               log_pi, log_A).decode(em[0])
po, so = oracle(em[0])
bit1 = bool(jnp.all(path1 == po)) and float(score1) == float(so)
ok &= bit1
acc = float(np.mean(np.asarray(path1) == truth))
dense_b = decoder_state_bytes("vanilla", K, T) + band.mask_bytes(K, T)
print(f"banded fused == dense oracle (bitwise): {bit1}   "
      f"match accuracy vs truth: {acc:.2f}")
print(f"state bytes: banded {banded_state_bytes(K, T, WIDTH):,} vs "
      f"dense+mask {dense_b:,}\n")

# 2. ragged batch: all B sensors in one launch, shared consensus band
lengths = np.array([T, T - 11, T - 29, 9])
paths, scores = ViterbiDecoder(FusedSpec(constraint=band), log_pi,
                               log_A).decode_batch(em, jnp.asarray(lengths))
bit2 = True
for i, L in enumerate(lengths):
    p, s = oracle(em[i, :L])
    bit2 &= bool(jnp.all(paths[i, :L] == p)) and float(scores[i]) == float(s)
ok &= bit2
print(f"batched ({B} sensors, ragged lengths={lengths.tolist()}) == "
      f"per-sensor dense oracle (bitwise): {bit2}\n")

# 3. streaming: feed fixes in chunks, commit matches at convergence points
stream = ViterbiDecoder(OnlineSpec(constraint=band), log_pi,
                        log_A).make_streaming()
committed = 0
for t0 in range(0, T, 16):
    committed += len(stream.feed(em[0, t0:t0 + 16]))
_, score3 = stream.flush()
bit3 = (bool(jnp.all(jnp.asarray(stream.path) == po))
        and float(score3) == float(so))
ok &= bit3
print(f"streaming == dense oracle (bitwise): {bit3}   "
      f"({committed}/{T} matches committed before the final flush)")

print(f"\nmap matching oracle-clean: {ok}")
sys.exit(0 if ok else 1)
